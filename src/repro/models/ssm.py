"""Mamba-2 (SSD — state-space duality) block, pure-JAX chunked implementation.

The chunked algorithm here is the oracle the Pallas SSD kernel
(``repro.kernels.ssd``) is validated against: within-chunk quadratic
(C B^T ⊙ decay) x, cross-chunk linear state recurrence.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Params = Dict[str, Any]
NEG_INF = -1e30


def init_ssm(cfg, key, dtype) -> Params:
    d = cfg.d_model
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    kin, kconv, kA, kdt, kout, knorm = jax.random.split(key, 6)
    d_in_proj = 2 * di + 2 * n + h          # z, x, B, C, dt
    conv_ch = di + 2 * n
    return {
        "w_in": (jax.random.normal(kin, (d, d_in_proj)) / math.sqrt(d)).astype(dtype),
        "conv_w": (jax.random.normal(kconv, (cfg.ssm_conv, conv_ch)) * 0.1).astype(dtype),
        "A_log": jnp.log(
            jax.random.uniform(kA, (h,), jnp.float32, minval=1.0, maxval=16.0)
        ),
        "dt_bias": jax.random.uniform(kdt, (h,), jnp.float32, minval=-4.0, maxval=-1.0),
        "D": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "w_out": (jax.random.normal(kout, (di, d)) / math.sqrt(di)).astype(dtype),
    }


def causal_depthwise_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: (B,S,C); w: (K,C).  y_t = sum_i w_i * x_{t-K+1+i} (causal)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    s = x.shape[1]
    for i in range(k):
        out = out + pad[:, i : i + s] * w[i]
    return out


def _split_proj(cfg, zxbcdt):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xin = zxbcdt[..., di : 2 * di]
    b = zxbcdt[..., 2 * di : 2 * di + n]
    c = zxbcdt[..., 2 * di + n : 2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n :]
    return z, xin, b, c, dt


def ssd_chunked(x, dt, a_log, b, c, chunk: int, init_state: Optional[jnp.ndarray] = None):
    """Chunked SSD scan.

    x: (B,S,H,P); dt: (B,S,H) (post-softplus); a_log: (H,) (negative A);
    b, c: (B,S,N) (single group).  Returns (y (B,S,H,P), state (B,H,P,N)).

    h_t = exp(dt_t A) h_{t-1} + dt_t * x_t ⊗ b_t ;  y_t = h_t c_t
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // q
    xq = x.reshape(bsz, nc, q, h, p)
    dtq = dt.reshape(bsz, nc, q, h).astype(jnp.float32)
    bq = b.reshape(bsz, nc, q, n).astype(jnp.float32)
    cq = c.reshape(bsz, nc, q, n).astype(jnp.float32)

    la = dtq * a_log[None, None, None, :]                      # (B,nc,Q,H) <= 0
    cs = jnp.cumsum(la, axis=2)                                # inclusive cumsum

    # ---- intra-chunk (quadratic within chunk) ----
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]          # (B,nc,Qi,Qj,H)
    mask = jnp.tril(jnp.ones((q, q), bool))
    seg = jnp.where(mask[None, None, :, :, None], seg, NEG_INF)
    dec = jnp.exp(seg)
    cb = jnp.einsum("bcin,bcjn->bcij", cq, bq)                 # (B,nc,Qi,Qj)
    xdt = xq.astype(jnp.float32) * dtq[..., None]              # (B,nc,Q,H,P)
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, dec, xdt)

    # ---- per-chunk final states ----
    sdec = jnp.exp(cs[:, :, -1:, :] - cs)                      # (B,nc,Q,H)
    s_chunk = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", bq, sdec, xdt)

    # ---- inter-chunk recurrence ----
    chunk_dec = jnp.exp(cs[:, :, -1, :])                       # (B,nc,H)
    h0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((bsz, h, p, n), jnp.float32)
    )

    def body(carry, inp):
        dec_c, s_c = inp                                       # (B,H), (B,H,P,N)
        new = carry * dec_c[:, :, None, None] + s_c
        return new, carry                                      # emit state *before* chunk

    if nc <= 64:
        # unrolled: XLA cost_analysis counts while bodies once (roofline).
        # Only this tiny elementwise recurrence lives in the loop — the
        # quadratic intra-chunk einsums above are vectorized over chunks —
        # so falling back to lax.scan beyond 64 chunks costs ~nothing in
        # cost-analysis accuracy while keeping HLO size bounded.
        carry, prev_list = h0, []
        for ci in range(nc):
            carry, prev = body(carry, (chunk_dec[:, ci], s_chunk[:, ci]))
            prev_list.append(prev)
        final = carry
        prevs = jnp.stack(prev_list, axis=1)                   # (B,nc,H,P,N)
    else:
        final, prevs = lax.scan(
            body, h0, (chunk_dec.transpose(1, 0, 2), s_chunk.transpose(1, 0, 2, 3, 4))
        )
        prevs = prevs.transpose(1, 0, 2, 3, 4)                 # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp", cq, jnp.exp(cs), prevs)
    y = (y_intra + y_inter).reshape(bsz, sp, h, p)[:, :s]
    return y.astype(x.dtype), final


def ssm_forward(cfg, p: Params, x: jnp.ndarray, state: Optional[Params] = None):
    """Full-sequence Mamba-2 block.  x: (B,S,d) -> (out, new_state|None)."""
    bsz, s, _ = x.shape
    di, n, h, ph = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_d_head
    zxbcdt = x @ p["w_in"]
    z, xin, b, c, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xin, b, c], axis=-1)
    if state is not None:
        conv_in_full = jnp.concatenate([state["conv"].astype(conv_in.dtype), conv_in], axis=1)
        conv_out = causal_depthwise_conv(conv_in_full, p["conv_w"])[:, cfg.ssm_conv - 1 :]
    else:
        conv_out = causal_depthwise_conv(conv_in, p["conv_w"])
    conv_out = jax.nn.silu(conv_out)
    xin, b, c = conv_out[..., :di], conv_out[..., di : di + n], conv_out[..., di + n :]
    xh = xin.reshape(bsz, s, h, ph)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a_log = -jnp.exp(p["A_log"])
    init_ssm_state = state["h"] if state is not None else None
    y, final = ssd_chunked(xh, dtv, a_log, b, c, cfg.ssm_chunk, init_ssm_state)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, di)
    # gated RMSNorm (Mamba-2): norm(y * silu(z))
    y = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    y = y * lax.rsqrt(jnp.mean(jnp.square(y), axis=-1, keepdims=True) + 1e-6)
    y = (y * p["norm_scale"].astype(jnp.float32)).astype(x.dtype)
    out = y @ p["w_out"]
    if state is None:
        return out, None
    new_conv = jnp.concatenate([state["conv"], conv_in], axis=1)[:, -(cfg.ssm_conv - 1) :]
    return out, {"conv": new_conv, "h": final}


def init_ssm_state(cfg, batch: int, dtype) -> Params:
    di, n, h, ph = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_d_head
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * n), dtype),
        "h": jnp.zeros((batch, h, ph, n), jnp.float32),
    }


def ssm_decode(cfg, p: Params, x: jnp.ndarray, state: Params):
    """Single-token step.  x: (B,1,d) -> (out (B,1,d), new_state)."""
    bsz = x.shape[0]
    di, n, h, ph = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_d_head
    zxbcdt = x[:, 0] @ p["w_in"]                               # (B, ...)
    z, xin, b, c, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xin, b, c], axis=-1)            # (B,C)
    window = jnp.concatenate([state["conv"].astype(conv_in.dtype), conv_in[:, None]], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"])
    conv_out = jax.nn.silu(conv_out)
    xin, b, c = conv_out[..., :di], conv_out[..., di : di + n], conv_out[..., di + n :]
    xh = xin.reshape(bsz, h, ph).astype(jnp.float32)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = jnp.exp(dtv * (-jnp.exp(p["A_log"])))                  # (B,H)
    hs = state["h"] * a[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dtv, xh, b.astype(jnp.float32)
    )
    y = jnp.einsum("bn,bhpn->bhp", c.astype(jnp.float32), hs)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(bsz, di) * jax.nn.silu(z.astype(jnp.float32))
    y = y * lax.rsqrt(jnp.mean(jnp.square(y), axis=-1, keepdims=True) + 1e-6)
    y = (y * p["norm_scale"].astype(jnp.float32)).astype(x.dtype)
    out = (y @ p["w_out"])[:, None]
    new_conv = window[:, 1:].astype(state["conv"].dtype)
    return out, {"conv": new_conv, "h": hs}
