"""Paged-decode attention Pallas kernel + fused K/V scatter epilogue.

The serving hot path (vLLM-style): each decode step attends one new
query token per slot against that slot's K/V pages, addressed through a
per-request page table.  The XLA reference in ``serve/kvcache.py``
materializes a contiguous ``(B, M*page, Hkv, D)`` gather every step;
this kernel never does — the page table is a *scalar-prefetch* operand,
so the kernel body reads ``table[b, j]`` itself and pulls exactly one
physical page at a time out of the HBM-resident pool.

TPU mapping: grid = (batch, kv_heads) — one program per (slot, kv head).
The K/V pools are ``memory_space=ANY`` operands (they stay in HBM; only
the touched pages ever move on-chip), and the kernel body walks the
request's table row with a ``fori_loop``, pulling two pages' K/V tiles
per iteration (a ``(2*page, D)`` block; odd trailing pages are padded by
a self-masking re-load of the last entry) and folding each block into an
online-softmax carry (m, l, acc) exactly like ``flash_attention.py``
folds k-blocks.
Keeping the page walk *inside* the program — rather than as a third,
sequential grid dimension — means the per-program dispatch cost is paid
``B*Hkv`` times instead of ``B*Hkv*M`` times, which is what makes the
kernel profitable even in interpret mode on CPU hosts; on a compiled
Mosaic build each page read lowers to a local HBM→VMEM copy (the
``pltpu.make_async_copy`` idiom, which also enables prefetching page
``j+1`` while page ``j`` is in the MXU).  The GQA group of
``G = Hq // Hkv`` query heads rides along as the block's row dimension,
so the score tile is a single ``(G, page)`` MXU matmul.  Under
``kv_quant`` the pages are int8 with per-(token, head) float32 scale
pages; dequant is fused into the page load (one multiply on the tile
already on-chip) instead of materializing a dequantized cache.

Causal masking needs no query position: decode queries sit at position
``pos[b]`` and every stored key at ``j*page + offset`` is valid iff it
is ``<= pos[b]`` (sliding window additionally requires
``> pos[b] - window``).  Pages past the live prefix belong to other
requests or the scratch page — their positions exceed ``pos[b]``, so
the same mask that implements causality also implements isolation.

The scatter (``paged_scatter_pallas``) is the write half of the step:
the new token's K/V row (and scale rows) land at
``pages[table[b, pos // page], pos % page]`` via ``input_output_aliases``
— an in-place block write into the existing page arrays, bit-identical
to the ``.at[page_idx, off].set()`` path (tier-1 asserted) without XLA's
copy-on-donate round trip.  The serving engine uses the *fused* form
(``paged_attention_scatter_pallas``): with a ``(B, Hkv)`` grid the
scatter is a prologue of the attention program itself — program (b, h)
writes only slot ``b``'s row at head ``h`` and then walks only slot
``b``'s pages, so the in-place store can never race another program's
page reads, and the whole read-modify-attend step is one dispatch.
(Idle slots are parked on the scratch page, which every program's mask
excludes, so even a torn scratch write is unobservable.)
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _load_page(pool_ref, scale_ref, pid, h):
    """One (page, D) tile out of an ANY-space pool, dequantized in-flight
    when the pool carries int8 pages + a float32 scale pool."""
    tile = pool_ref[pl.ds(pid, 1), :, h, :][0].astype(jnp.float32)
    if scale_ref is not None:
        tile = tile * scale_ref[pl.ds(pid, 1), :, h][0][:, None]
    return tile


def _page_walk(tbl_ref, b, h, q, p0, k_ref, v_ref, ks_ref, vs_ref,
               *, scale: float, window: int, page: int, n_pages: int):
    """Online-softmax walk over one request's table row, two pages per
    iteration (halves loop-carry overhead; the score tile is a single
    ``(G, 2*page)`` MXU matmul).  For odd ``n_pages`` the trailing
    phantom page re-loads the last table entry, but its key positions
    ``>= n_pages * page`` exceed every legal ``pos`` — the causal mask
    zeroes it, so no separate epilogue iteration is needed.  Returns the
    normalized (G, D) float32 attention output."""
    g, d = q.shape

    def body(jj, carry):
        m_prev, l_prev, acc = carry
        j0 = 2 * jj
        j1 = jnp.minimum(j0 + 1, n_pages - 1)
        pa = tbl_ref[b, j0]                            # the gather
        pb = tbl_ref[b, j1]
        k = jnp.concatenate(
            [_load_page(k_ref, ks_ref, pa, h), _load_page(k_ref, ks_ref, pb, h)],
            axis=0,
        )                                              # (2*page, D)
        v = jnp.concatenate(
            [_load_page(v_ref, vs_ref, pa, h), _load_page(v_ref, vs_ref, pb, h)],
            axis=0,
        )

        s = (q @ k.T) * scale                          # (G, 2*page) — MXU
        iota = jax.lax.broadcasted_iota(jnp.int32, (g, page), 1)
        k_pos = jnp.concatenate(
            [j0 * page + iota, (j0 + 1) * page + iota], axis=1
        )                                              # phantom half masks itself
        valid = k_pos <= p0
        if window:
            valid &= k_pos > p0 - window
        s = jnp.where(valid, s, NEG_INF)

        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                         # (G, 2*page)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + p @ v                       # (G, D) — MXU
        return m_new, l_new, acc

    init = (jnp.full((g, 1), NEG_INF, jnp.float32),    # m (running max)
            jnp.zeros((g, 1), jnp.float32),            # l (running denom)
            jnp.zeros((g, d), jnp.float32))            # acc (weighted values)
    m_f, l_f, acc = jax.lax.fori_loop(0, (n_pages + 1) // 2, body, init)
    return acc / jnp.maximum(l_f, 1e-20)


def _paged_kernel(tbl_ref, pos_ref, q_ref, k_ref, v_ref, *rest,
                  scale: float, window: int, page: int, n_pages: int,
                  quant: bool):
    if quant:
        ks_ref, vs_ref, o_ref = rest
    else:
        ks_ref = vs_ref = None
        o_ref, = rest
    b = pl.program_id(0)
    h = pl.program_id(1)
    q = q_ref[0, 0].astype(jnp.float32)                # (G, D)
    out = _page_walk(tbl_ref, b, h, q, pos_ref[b], k_ref, v_ref, ks_ref,
                     vs_ref, scale=scale, window=window, page=page,
                     n_pages=n_pages)
    o_ref[0, 0] = out.astype(o_ref.dtype)


def paged_attention_pallas(
    q: jnp.ndarray,
    k_pages: jnp.ndarray,
    v_pages: jnp.ndarray,
    table: jnp.ndarray,
    pos: jnp.ndarray,
    *,
    k_scale_pages: Optional[jnp.ndarray] = None,
    v_scale_pages: Optional[jnp.ndarray] = None,
    window: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    """Single-token decode attention over paged KV.

    q: (B, Hkv, G, D) post-RoPE queries (GQA groups under their kv head);
    k_pages/v_pages: (P, page, Hkv, D) physical pool (int8 when quantized);
    k/v_scale_pages: (P, page, Hkv) float32 dequant scales (or None);
    table: (B, M) int32 page table; pos: (B,) int32 query positions.
    Returns (B, Hkv, G, D) in q.dtype.
    """
    bsz, hkv, g, d = q.shape
    page = k_pages.shape[1]
    m = table.shape[1]
    quant = k_scale_pages is not None
    scale = 1.0 / math.sqrt(d)

    # table/pos are scalar-prefetch operands: available before the body
    # runs, so the fori_loop can chase ``table[b, j]`` page indices.  The
    # pools are ANY-space refs — never block-mapped, only the pages the
    # loop touches are read.
    q_spec = pl.BlockSpec((1, 1, g, d), lambda b, h, tbl, ps: (b, h, 0, 0))
    kv_spec = pl.BlockSpec(memory_space=pltpu.ANY)
    in_specs = [q_spec, kv_spec, kv_spec]
    args = [q, k_pages, v_pages]
    if quant:
        in_specs += [kv_spec, kv_spec]
        args += [k_scale_pages, v_scale_pages]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bsz, hkv),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b, h, tbl, ps: (b, h, 0, 0)),
    )
    return pl.pallas_call(
        functools.partial(
            _paged_kernel, scale=scale, window=window, page=page,
            n_pages=m, quant=quant,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, hkv, g, d), q.dtype),
        interpret=interpret,
    )(table, pos, *args)


def _paged_step_kernel(tbl_ref, pos_ref, pidx_ref, offw_ref, q_ref, *rest,
                       scale: float, window: int, page: int, n_pages: int,
                       quant: bool):
    # rest = (*new_rows, *pool_inputs, o, *pool_outputs); the pool outputs
    # alias the pool inputs, so the body only ever touches the output refs
    if quant:
        kn_ref, vn_ref, ksn_ref, vsn_ref = rest[:4]
        o_ref, k_ref, v_ref, ks_ref, vs_ref = rest[8:]
    else:
        kn_ref, vn_ref = rest[:2]
        ks_ref = vs_ref = None
        o_ref, k_ref, v_ref = rest[4:]
    b = pl.program_id(0)
    h = pl.program_id(1)

    # scatter prologue: land the new token's row in its page (in place,
    # aliased) *before* the walk, so the walk attends to it.  Program
    # (b, h) writes only slot b's row at head h and reads only slot b's
    # pages at head h — no cross-program hazard.
    pw = pidx_ref[b]
    ow = offw_ref[b]
    k_ref[pl.ds(pw, 1), pl.ds(ow, 1), h, :] = kn_ref[0, 0][None, None, :]
    v_ref[pl.ds(pw, 1), pl.ds(ow, 1), h, :] = vn_ref[0, 0][None, None, :]
    if quant:
        ks_ref[pl.ds(pw, 1), pl.ds(ow, 1), h] = ksn_ref[0, 0][None, None]
        vs_ref[pl.ds(pw, 1), pl.ds(ow, 1), h] = vsn_ref[0, 0][None, None]

    q = q_ref[0, 0].astype(jnp.float32)                # (G, D)
    out = _page_walk(tbl_ref, b, h, q, pos_ref[b], k_ref, v_ref, ks_ref,
                     vs_ref, scale=scale, window=window, page=page,
                     n_pages=n_pages)
    o_ref[0, 0] = out.astype(o_ref.dtype)


def paged_attention_scatter_pallas(
    q: jnp.ndarray,
    k_new: jnp.ndarray,
    v_new: jnp.ndarray,
    k_pages: jnp.ndarray,
    v_pages: jnp.ndarray,
    table: jnp.ndarray,
    pos: jnp.ndarray,
    page_idx: jnp.ndarray,
    off: jnp.ndarray,
    *,
    k_scale_new: Optional[jnp.ndarray] = None,
    v_scale_new: Optional[jnp.ndarray] = None,
    k_scale_pages: Optional[jnp.ndarray] = None,
    v_scale_pages: Optional[jnp.ndarray] = None,
    window: int = 0,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, ...]]:
    """Fused decode step: scatter the new K/V row, then attend — one
    dispatch.  Bit-identical to ``paged_scatter_pallas`` followed by
    ``paged_attention_pallas`` (tier-1 asserted).

    k_new/v_new: (B, Hkv, D) the new token's K/V rows (pool dtype —
    already quantized when the pool is int8); k/v_scale_new: (B, Hkv)
    their dequant scales; page_idx/off: (B,) int32 write destinations
    (idle slots point at the scratch page).  Other shapes as
    :func:`paged_attention_pallas`.  Returns ``(out, updated_pools)``.
    """
    bsz, hkv, g, d = q.shape
    page = k_pages.shape[1]
    m = table.shape[1]
    quant = k_scale_pages is not None
    scale = 1.0 / math.sqrt(d)

    q_spec = pl.BlockSpec((1, 1, g, d), lambda b, h, *s: (b, h, 0, 0))
    row_spec = pl.BlockSpec((1, 1, d), lambda b, h, *s: (b, h, 0))
    any_spec = pl.BlockSpec(memory_space=pltpu.ANY)
    if quant:
        srow_spec = pl.BlockSpec((1, 1), lambda b, h, *s: (b, h))
        new_specs = [row_spec, row_spec, srow_spec, srow_spec]
        news = [k_new, v_new, k_scale_new, v_scale_new]
        pools = [k_pages, v_pages, k_scale_pages, v_scale_pages]
    else:
        new_specs = [row_spec, row_spec]
        news = [k_new, v_new]
        pools = [k_pages, v_pages]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,                 # table, pos, page_idx, off
        grid=(bsz, hkv),
        in_specs=[q_spec] + new_specs + [any_spec] * len(pools),
        out_specs=[q_spec] + [any_spec] * len(pools),
    )
    base = 4 + 1 + len(news)                   # scalar-prefetch + q + rows
    out = pl.pallas_call(
        functools.partial(_paged_step_kernel, scale=scale, window=window,
                          page=page, n_pages=m, quant=quant),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((bsz, hkv, g, d), q.dtype)]
                  + [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in pools],
        input_output_aliases={base + i: 1 + i for i in range(len(pools))},
        interpret=interpret,
    )(table, pos, page_idx, off, q, *news, *pools)
    return out[0], tuple(out[1:])


def _scatter_kernel(pi_ref, off_ref, *refs, n_arrays: int):
    # refs = (*page_inputs, *new_rows, *page_outputs); the page outputs
    # alias the page inputs, so the only work is one row store per array
    news = refs[n_arrays:2 * n_arrays]
    outs = refs[2 * n_arrays:]
    for new_ref, o_ref in zip(news, outs):
        o_ref[0, 0] = new_ref[0]


def paged_scatter_pallas(
    pages: Sequence[jnp.ndarray],
    new_rows: Sequence[jnp.ndarray],
    page_idx: jnp.ndarray,
    off: jnp.ndarray,
    *,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, ...]:
    """Scatter each slot's new token row into its page, in place.

    pages[i]: (P, page, ...) pool array; new_rows[i]: (B, ...) the new
    token's row per slot; page_idx/off: (B,) int32 destinations.  All
    arrays share one grid pass (one call updates k, v and both scale
    pools).  Idle slots target (SCRATCH_PAGE, 0); the grid is sequential
    so coinciding writes resolve last-wins, and scratch is never read.
    """
    n = len(pages)
    bsz = new_rows[0].shape[0]

    def page_spec(a):
        blk = (1, 1) + a.shape[2:]
        zeros = (0,) * (a.ndim - 2)
        return pl.BlockSpec(blk, lambda b, pi, of, z=zeros: (pi[b], of[b]) + z)

    def row_spec(a):
        blk = (1,) + a.shape[1:]
        zeros = (0,) * (a.ndim - 1)
        return pl.BlockSpec(blk, lambda b, pi, of, z=zeros: (b,) + z)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bsz,),
        in_specs=[page_spec(a) for a in pages] + [row_spec(a) for a in new_rows],
        out_specs=[page_spec(a) for a in pages],
    )
    out = pl.pallas_call(
        functools.partial(_scatter_kernel, n_arrays=n),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(a.shape, a.dtype) for a in pages],
        # operand indices count the 2 scalar-prefetch refs
        input_output_aliases={2 + i: i for i in range(n)},
        interpret=interpret,
    )(page_idx, off, *pages, *new_rows)
    return tuple(out)
