"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

Deliberately the *simplest possible* implementations — sequential scans,
materialized attention — no chunking tricks shared with the kernels.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def attention_ref(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    *, causal: bool = True, window: int = 0,
) -> jnp.ndarray:
    """q: (B,Hq,S,D); k/v: (B,Hkv,S,D).  Materialized-scores attention."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, s, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg, kf) / math.sqrt(d)
    pos = jnp.arange(s)
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= pos[None, :] <= pos[:, None]
    if window:
        mask &= pos[None, :] > pos[:, None] - window
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, s, d).astype(q.dtype)


def paged_attention_ref(
    q: jnp.ndarray, k_pages: jnp.ndarray, v_pages: jnp.ndarray,
    table: jnp.ndarray, pos: jnp.ndarray,
    *, k_scale_pages=None, v_scale_pages=None, window: int = 0,
) -> jnp.ndarray:
    """Materialized paged decode attention: gather the full (B, M*page)
    view through the table, dequantize, mask by position, softmax.
    Shapes as :func:`repro.kernels.paged_attention.paged_attention_pallas`."""
    b, hkv, g, d = q.shape
    page = k_pages.shape[1]
    t = table.shape[1] * page
    ck = k_pages[table].reshape(b, t, hkv, d).astype(jnp.float32)
    cv = v_pages[table].reshape(b, t, hkv, d).astype(jnp.float32)
    if k_scale_pages is not None:
        ck = ck * k_scale_pages[table].reshape(b, t, hkv)[..., None]
        cv = cv * v_scale_pages[table].reshape(b, t, hkv)[..., None]
    s = jnp.einsum("bkgd,btkd->bkgt", q.astype(jnp.float32), ck) / math.sqrt(d)
    k_pos = jnp.arange(t, dtype=jnp.int32)
    valid = k_pos[None, :] <= pos[:, None]
    if window:
        valid &= k_pos[None, :] > pos[:, None] - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, cv)
    return out.astype(q.dtype)


def ssd_ref(
    x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
    b: jnp.ndarray, c: jnp.ndarray,
) -> jnp.ndarray:
    """Sequential (per-step) SSD recurrence.  Shapes as ssd_pallas."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp                     # (B,H,P),(B,H),(B,N),(B,N)
        decay = jnp.exp(dtt * a_log[None, :])     # (B,H)
        state = state * decay[..., None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dtt, xt, bt
        )
        y = jnp.einsum("bn,bhpn->bhp", ct, state)
        return state, y

    state0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    xs = (
        x.transpose(1, 0, 2, 3).astype(jnp.float32),
        dt.transpose(1, 0, 2).astype(jnp.float32),
        b.transpose(1, 0, 2).astype(jnp.float32),
        c.transpose(1, 0, 2).astype(jnp.float32),
    )
    _, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)


def rglru_scan_ref(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray) -> jnp.ndarray:
    """Sequential h_t = a_t h_{t-1} + b_t.  a,b: (B,S,W); h0: (B,W)."""

    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    xs = (a.transpose(1, 0, 2).astype(jnp.float32), b.transpose(1, 0, 2).astype(jnp.float32))
    _, hs = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return hs.transpose(1, 0, 2).astype(a.dtype)
