"""Jit'd dispatch wrappers for the Pallas kernels.

On a CPU host (this container) the kernels execute in interpret mode —
the kernel body runs as traced JAX ops, validating BlockSpec indexing and
numerics; on a TPU backend the same call sites compile to Mosaic.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rglru_scan import rglru_scan_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ssd import ssd_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("eps", "row_block"))
def rmsnorm(x, scale, eps: float = 1e-6, row_block: int = 256):
    return rmsnorm_pallas(x, scale, eps=eps, row_block=row_block, interpret=_interpret())


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128):
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_interpret(),
    )


@partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, a_log, b, c, chunk: int = 128):
    return ssd_pallas(x, dt, a_log, b, c, chunk=chunk, interpret=_interpret())


@partial(jax.jit, static_argnames=("chunk", "width_block"))
def rglru_scan(a, b, h0, chunk: int = 64, width_block: int = 512):
    return rglru_scan_pallas(
        a, b, h0, chunk=chunk, width_block=width_block, interpret=_interpret()
    )
