"""Jit'd dispatch wrappers for the Pallas kernels.

On a CPU host (this container) the kernels execute in interpret mode —
the kernel body runs as traced JAX ops, validating BlockSpec indexing and
numerics; on a TPU backend the same call sites compile to Mosaic.

``REPRO_PALLAS_INTERPRET=0/1`` overrides the platform default (CI forces
the interpret branch explicitly; TPU users can A/B interpret mode).  The
flag is read at trace time: wrappers are jitted, so flipping the env var
after a shape has compiled does not retrace it.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.paged_attention import (
    paged_attention_pallas,
    paged_attention_scatter_pallas,
    paged_scatter_pallas,
)
from repro.kernels.rglru_scan import rglru_scan_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ssd import ssd_pallas


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "").strip().lower()
    if env in ("1", "true", "yes", "on"):
        return True
    if env in ("0", "false", "no", "off"):
        return False
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("eps", "row_block"))
def rmsnorm(x, scale, eps: float = 1e-6, row_block: int = 256):
    return rmsnorm_pallas(x, scale, eps=eps, row_block=row_block, interpret=_interpret())


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128):
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_interpret(),
    )


@partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, a_log, b, c, chunk: int = 128):
    return ssd_pallas(x, dt, a_log, b, c, chunk=chunk, interpret=_interpret())


@partial(jax.jit, static_argnames=("chunk", "width_block"))
def rglru_scan(a, b, h0, chunk: int = 64, width_block: int = 512):
    return rglru_scan_pallas(
        a, b, h0, chunk=chunk, width_block=width_block, interpret=_interpret()
    )


@partial(jax.jit, static_argnames=("window",))
def paged_attention(q, k_pages, v_pages, table, pos, window: int = 0):
    """q: (B,Hkv,G,D); pages: (P,page,Hkv,D); table: (B,M); pos: (B,)."""
    return paged_attention_pallas(
        q, k_pages, v_pages, table, pos, window=window, interpret=_interpret()
    )


@partial(jax.jit, static_argnames=("window",))
def paged_attention_quant(q, k_pages, v_pages, k_scale_pages, v_scale_pages,
                          table, pos, window: int = 0):
    """int8 pages + (P,page,Hkv) float32 scale pages, dequant fused in."""
    return paged_attention_pallas(
        q, k_pages, v_pages, table, pos,
        k_scale_pages=k_scale_pages, v_scale_pages=v_scale_pages,
        window=window, interpret=_interpret(),
    )


@partial(jax.jit, static_argnames=("window",))
def paged_attention_scatter(q, k_new, v_new, k_pages, v_pages, table, pos,
                            page_idx, off, window: int = 0):
    """Fused decode step (scatter prologue + paged attention, one
    dispatch).  Returns ``(out, (k_pages, v_pages))``."""
    return paged_attention_scatter_pallas(
        q, k_new, v_new, k_pages, v_pages, table, pos, page_idx, off,
        window=window, interpret=_interpret(),
    )


@partial(jax.jit, static_argnames=("window",))
def paged_attention_scatter_quant(q, k_new, v_new, k_scale_new, v_scale_new,
                                  k_pages, v_pages, k_scale_pages,
                                  v_scale_pages, table, pos, page_idx, off,
                                  window: int = 0):
    """Fused decode step over int8 pages; the prologue also lands the new
    row's scales, the walk dequants in-flight.  Returns
    ``(out, (k_pages, v_pages, k_scale_pages, v_scale_pages))``."""
    return paged_attention_scatter_pallas(
        q, k_new, v_new, k_pages, v_pages, table, pos, page_idx, off,
        k_scale_new=k_scale_new, v_scale_new=v_scale_new,
        k_scale_pages=k_scale_pages, v_scale_pages=v_scale_pages,
        window=window, interpret=_interpret(),
    )


@jax.jit
def paged_scatter(k_pages, v_pages, k_new, v_new, page_idx, off):
    """In-place (aliased) scatter of each slot's new K/V row into its page."""
    return paged_scatter_pallas(
        (k_pages, v_pages), (k_new, v_new), page_idx, off,
        interpret=_interpret(),
    )


@jax.jit
def paged_scatter_quant(k_pages, v_pages, k_scale_pages, v_scale_pages,
                        k_new, v_new, k_scale_new, v_scale_new, page_idx, off):
    """One grid pass updates the int8 K/V pages and both scale pools."""
    return paged_scatter_pallas(
        (k_pages, v_pages, k_scale_pages, v_scale_pages),
        (k_new, v_new, k_scale_new, v_scale_new),
        page_idx, off, interpret=_interpret(),
    )
