"""RG-LRU linear-recurrence Pallas kernel: h_t = a_t * h_{t-1} + b_t.

TPU mapping: grid = (batch, width_blocks, time_chunks) — time is the LAST
(sequential) grid axis so the hidden state (one (1, BW) VREG-friendly row)
persists in VMEM scratch across chunks.  The recurrence is elementwise over
the width lanes (VPU, not MXU); within a chunk a ``fori_loop`` steps time,
which on TPU pipelines loads from the VMEM tile.  Width blocks of 512-1024
lanes keep the tile well-shaped (8x128 packing).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h0_ref, y_ref, h_scr, *, q: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)     # (1, BW) -> (BW,)

    a = a_ref[0].astype(jnp.float32)                   # (Q, BW)
    b = b_ref[0].astype(jnp.float32)                   # (Q, BW)

    def step(t, h):
        h = a[t] * h + b[t]
        y_ref[0, t] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, q, step, h_scr[...])
    h_scr[...] = h


def rglru_scan_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    h0: jnp.ndarray,
    *,
    chunk: int = 64,
    width_block: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """a, b: (B, S, W); h0: (B, W).  Returns h: (B, S, W)."""
    bsz, s, w = a.shape
    q = min(chunk, s)
    bw = min(width_block, w)
    pad_s = (-s) % q
    pad_w = (-w) % bw
    if pad_s or pad_w:
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, pad_w)))
        b = jnp.pad(b, ((0, 0), (0, pad_s), (0, pad_w)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_w)))
    sp, wp = s + pad_s, w + pad_w

    y = pl.pallas_call(
        functools.partial(_rglru_kernel, q=q),
        grid=(bsz, wp // bw, sp // q),
        in_specs=[
            pl.BlockSpec((1, q, bw), lambda bi, wi, j: (bi, j, wi)),
            pl.BlockSpec((1, q, bw), lambda bi, wi, j: (bi, j, wi)),
            pl.BlockSpec((1, bw), lambda bi, wi, j: (bi, wi)),
        ],
        out_specs=pl.BlockSpec((1, q, bw), lambda bi, wi, j: (bi, j, wi)),
        out_shape=jax.ShapeDtypeStruct((bsz, sp, wp), a.dtype),
        scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return y[:, :s, :w]
