"""Fused RMSNorm forward kernel.

Grid: rows of the flattened (tokens, d_model) input, one (ROW_BLOCK, D)
VMEM tile per step — norm statistics never leave VMEM, one HBM read and one
HBM write per element (vs 3 reads for the unfused mean-square/normalize/
scale sequence).  D is expected to be a multiple of 128 (lane width).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                 # (R, D)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(
    x: jnp.ndarray,
    scale: jnp.ndarray,
    *,
    eps: float = 1e-6,
    row_block: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """x: (..., D); scale: (D,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    rows = xf.shape[0]
    rb = min(row_block, rows)
    pad = (-rows) % rb
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=((rows + pad) // rb,),
        in_specs=[
            pl.BlockSpec((rb, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
