"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel ships three pieces (per repo convention):
  <name>.py - the pl.pallas_call with explicit BlockSpec VMEM tiling,
  ops.py    - jit'd dispatch wrappers (interpret=True on CPU hosts),
  ref.py    - the pure-jnp oracle the tests assert against.

The COUNTDOWN Slack paper itself contributes no compute kernel (it is a
power-management runtime); these kernels cover the hot spots of the
framework the technique is embedded in: attention (flash, causal/banded/
GQA), RMSNorm, the Mamba-2 SSD chunked scan, and the RG-LRU linear
recurrence.
"""
