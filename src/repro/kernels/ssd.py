"""Mamba-2 SSD (state-space duality) chunked-scan Pallas kernel.

Recurrence: h_t = exp(dt_t * A) h_{t-1} + dt_t * x_t ⊗ b_t ;  y_t = h_t c_t.

TPU mapping: grid = (batch*heads, n_chunks) — the chunk axis is the LAST
(sequential) grid dimension, so the (N x P) inter-chunk state lives in VMEM
scratch and is carried across chunks, exactly the paper-standard SSD
decomposition: a (Q x Q) intra-chunk quadratic part (two MXU matmuls) plus
a rank-N state pass.  Per step the kernel touches one (Q,P) x-tile, one
(Q,N) b/c tile and the (N,P) state — all VMEM-resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr, *, q: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)                   # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)                 # (Q,)
    a = a_ref[0].astype(jnp.float32)                   # ()  negative
    b = b_ref[0].astype(jnp.float32)                   # (Q, N)
    c = c_ref[0].astype(jnp.float32)                   # (Q, N)

    la = dt * a                                        # (Q,) log-decay <= 0
    cs = jnp.cumsum(la)                                # inclusive
    xdt = x * dt[:, None]                              # (Q, P)

    # ---- intra-chunk quadratic (MXU) ----
    seg = cs[:, None] - cs[None, :]                    # (Qi, Qj)
    mask = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (q, q), 1
    )
    dec = jnp.exp(jnp.where(mask, seg, NEG_INF))
    cb = (c @ b.T) * dec                               # (Q, Q)
    y = cb @ xdt                                       # (Q, P)

    # ---- inter-chunk state contribution ----
    state = state_scr[...]                             # (N, P)
    y += (c * jnp.exp(cs)[:, None]) @ state            # (Q,N)@(N,P)

    # ---- state update (xdt already carries the dt factor) ----
    sdec = jnp.exp(cs[-1] - cs)                        # (Q,)
    state_scr[...] = state * jnp.exp(cs[-1]) + (b * sdec[:, None]).T @ xdt

    y_ref[0] = y.astype(y_ref.dtype)


def ssd_pallas(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    a_log: jnp.ndarray,
    b: jnp.ndarray,
    c: jnp.ndarray,
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """x: (B,S,H,P); dt: (B,S,H); a_log: (H,); b,c: (B,S,N) -> y (B,S,H,P)."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // q

    # (B,H,S,P) etc. so the (batch*head) grid axis is leading
    xt = x.transpose(0, 2, 1, 3).reshape(bsz * h, sp, p)
    dtt = dt.transpose(0, 2, 1).reshape(bsz * h, sp)
    at = jnp.tile(a_log[None, :], (bsz, 1)).reshape(bsz * h)
    bt = jnp.repeat(b[:, None], h, axis=1).reshape(bsz * h, sp, n)
    ct = jnp.repeat(c[:, None], h, axis=1).reshape(bsz * h, sp, n)

    y = pl.pallas_call(
        functools.partial(_ssd_kernel, q=q),
        grid=(bsz * h, nc),
        in_specs=[
            pl.BlockSpec((1, q, p), lambda g, j: (g, j, 0)),
            pl.BlockSpec((1, q), lambda g, j: (g, j)),
            pl.BlockSpec((1,), lambda g, j: (g,)),
            pl.BlockSpec((1, q, n), lambda g, j: (g, j, 0)),
            pl.BlockSpec((1, q, n), lambda g, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, p), lambda g, j: (g, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz * h, sp, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, at, bt, ct)
    y = y.reshape(bsz, h, sp, p).transpose(0, 2, 1, 3)
    return y[:, :s]
