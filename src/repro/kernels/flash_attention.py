"""Flash attention (blockwise online-softmax) Pallas kernel.

TPU mapping: grid = (batch, q_heads, q_blocks, k_blocks) with the LAST grid
dimension sequential on TPU, so the online-softmax state (m, l, acc) lives
in VMEM scratch and is carried across k-blocks; the output tile is written
on the final k-block.  Q/K/V tiles are MXU-aligned (block sizes multiples
of 128 on the contracted/lane dims).  GQA folds the group into the q-head
grid axis and maps k/v through ``h // group``.  Causal and sliding-window
masks are applied from absolute block positions.

Why this shape: on TPU the (Bq x D) @ (D x Bk) score tile and the
(Bq x Bk) @ (Bk x D) value tile both hit the MXU; keeping m/l/acc in
scratch makes HBM traffic O(S*D) instead of O(S^2).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  bq: int, bk: int, n_k_blocks: int):
    j = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # (Bq, D)
    k = k_ref[0, 0].astype(jnp.float32)                # (Bk, D)
    v = v_ref[0, 0].astype(jnp.float32)                # (Bk, D)

    s = q @ k.T                                        # (Bq, Bk) — MXU
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                # (Bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                             # (Bq, Bk)
    corr = jnp.exp(m_prev - m_new)                     # (Bq, 1)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + p @ v         # (Bq, D) — MXU
    m_scr[...] = m_new

    @pl.when(j == n_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """q: (B, Hq, S, D); k/v: (B, Hkv, S, D) with Hq % Hkv == 0 (GQA)."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    bq = min(block_q, s)
    bk = min(block_k, s)
    pad_q = (-s) % bq
    pad_k = (-s) % bk
    if pad_q or pad_k:
        # padded keys live at positions >= s; causal mask plus the padded
        # q positions being discarded keeps results exact
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    sq, sk = s + pad_q, s + pad_k
    n_q, n_k = sq // bq, sk // bk
    scale = 1.0 / math.sqrt(d)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, window=window,
            bq=bq, bk=bk, n_k_blocks=n_k,
        ),
        grid=(b, hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, h, i, j: (bi, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, h, i, j, g=group: (bi, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, h, i, j, g=group: (bi, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bi, h, i, j: (bi, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),          # m (running max)
            pltpu.VMEM((bq, 1), jnp.float32),          # l (running denom)
            pltpu.VMEM((bq, d), jnp.float32),          # acc (weighted values)
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :s]
