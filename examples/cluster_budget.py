"""Cluster power budget: a train job + a bursty serve job under one cap.

  PYTHONPATH=src python examples/cluster_budget.py

Two simulated tenants share a 100 W cluster cap: a compute-bound training
job (EP-like — every watt converts to progress) and a bursty-serve job
(decode-shaped — most of its rank-time is slack).  The
``PowerBudgetArbiter`` polls each job's exploited-slack ratio every epoch
and re-splits the cap with AIMD steps; this prints the per-epoch watt
reallocation, then compares the outcome against static equal-split.
"""
from repro.cluster import (
    PowerBudgetArbiter,
    StaticEqualSplit,
    make_job,
    run_coschedule,
)

CAP_W = 100.0
FLOOR_W = 15.0


def mix():
    return [
        make_job("compute_bound", job_id="train", seed=1, floor_w=FLOOR_W),
        make_job("bursty_serve", job_id="serve", seed=2, floor_w=FLOOR_W),
    ]


def main() -> None:
    print(f"cluster cap {CAP_W:.0f} W, per-job floor {FLOOR_W:.0f} W\n")
    print("epoch   train W   serve W   (exploited-slack ratio train / serve)")

    jobs = mix()
    by_id = {j.job_id: j for j in jobs}

    def show(epoch, alloc):
        ratios = []
        for jid in ("train", "serve"):
            job = by_id[jid]
            ratios.append(job.reports[-1].exploited_ratio if job.reports else 0.0)
        print(f"  {epoch:3d}  {alloc.get('train', 0.0):7.1f}  "
              f"{alloc.get('serve', 0.0):7.1f}    ({ratios[0]:.3f} / {ratios[1]:.3f})")

    arbited = run_coschedule(
        jobs, CAP_W,
        arbiter=PowerBudgetArbiter(cap_w=CAP_W, floor_w=FLOOR_W),
        on_epoch=show,
    )
    static = run_coschedule(
        mix(), CAP_W, arbiter=StaticEqualSplit(cap_w=CAP_W, floor_w=FLOOR_W)
    )

    print("\ndiscipline        makespan      energy")
    for r in (static, arbited):
        print(f"  {r.discipline:22s} {r.makespan_s:6.2f} s  {r.energy_j:7.0f} J")
    saving = 100.0 * (1.0 - arbited.energy_j / static.energy_j)
    overhead = 100.0 * (arbited.makespan_s / static.makespan_s - 1.0)
    print(f"\narbiter vs static: {saving:+.1f}% energy at {overhead:+.1f}% makespan")
    assert saving > 0.0 and overhead <= 1.0, "arbiter should win this mix"


if __name__ == "__main__":
    main()
