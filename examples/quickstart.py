"""Quickstart: 60 seconds to a trained (tiny) LM + COUNTDOWN Slack analysis.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax

from repro.configs import get_config, reduced
from repro.core.policies import ALL_POLICIES, BASELINE
from repro.core.simulator import simulate
from repro.core.workloads import APPS, generate
from repro.train.data import DataLoader
from repro.train.loop import init_state, make_train_step
from repro.train.optimizer import OptConfig


def main() -> None:
    # ---- 1. train a tiny LM with the framework's substrate ----
    cfg = reduced(get_config("countdown-100m"), n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256)
    opt_cfg = OptConfig(lr=3e-3, warmup_steps=5, total_steps=40)
    state = init_state(cfg, opt_cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt_cfg))
    loader = DataLoader(cfg, batch=8, seq_len=33)
    print("training a tiny LM:")
    for i, batch in zip(range(40), loader):
        state, m = step(state, batch)
        if i % 10 == 0 or i == 39:
            print(f"  step {i:3d}  loss {float(m['loss']):.3f}")
    loader.close()

    # ---- 2. the paper: COUNTDOWN Slack on a calibrated HPC workload ----
    print("\nCOUNTDOWN Slack on the omen_1056p workload (paper §6.4):")
    wl = generate(APPS["omen_1056p"], seed=0)
    base, _ = simulate(wl, BASELINE)
    for pol in ("minfreq", "countdown", "cntd_slack"):
        res, _ = simulate(wl, ALL_POLICIES[pol])
        print(
            f"  {pol:12s} overhead {res.overhead_vs(base):6.2f}%   "
            f"energy saving {res.energy_saving_vs(base):6.2f}%"
        )
    print("\n-> COUNTDOWN Slack: energy saving at (near-)zero overhead.")


if __name__ == "__main__":
    main()
