"""COUNTDOWN Slack LIVE: data-parallel training with instrumented collectives.

This is the paper's runtime working end-to-end on real execution (not the
simulator): 8 (emulated) devices train data-parallel under shard_map; every
gradient all-reduce goes through ``cd_psum`` which (i) inserts the
artificial barrier and (ii) emits host phase events; the Governor
reconstructs per-rank slack, applies the 500 us timeout policy, logs the
P-state actuations it would issue, estimates energy saving, and feeds the
straggler detector.

  PYTHONPATH=src python examples/energy_aware_training.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, reduced
from repro.core import instrument
from repro.core.governor import Governor
from repro.core.instrument import cd_psum
from repro.core.policies import COUNTDOWN_SLACK
from repro.dist.compat import set_mesh, shard_map
from repro.models.inputs import make_batch
from repro.models.transformer import init_params, loss_fn
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state


def main() -> None:
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",))
    cfg = reduced(get_config("countdown-100m"), n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256)
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=2, total_steps=30)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params, opt_cfg)

    governor = Governor(policy=COUNTDOWN_SLACK)
    instrument.set_mode("profile")
    instrument.enable_events(True)          # fully-manual mesh: events legal
    instrument.get_event_bus().subscribe(governor)

    def per_device_step(params, opt, batch):
        # Tcomp: local forward/backward -- then the instrumented collective:
        # artificial barrier (isolates slack) + the real grad all-reduce.
        loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, batch)[0])(params)
        grads = cd_psum(grads, "data")
        grads = jax.tree.map(lambda g: g / n_dev, grads)
        loss = cd_psum(loss, "data") / n_dev
        params, opt, _ = adamw_update(params, grads, opt, opt_cfg)
        return params, opt, loss

    # fully-specified jit shardings: the production idiom, and required on
    # the pinned container jax (the profile-mode io_callback token otherwise
    # desyncs XLA's sharding-propagation parameter vector)
    repl = NamedSharding(mesh, P())
    dsh = NamedSharding(mesh, P("data"))
    params = jax.device_put(params, jax.tree.map(lambda _: repl, params))
    opt = jax.device_put(opt, jax.tree.map(lambda _: repl, opt))
    step = jax.jit(
        shard_map(
            per_device_step,
            mesh=mesh,
            in_specs=(P(), P(), P("data")),
            out_specs=(P(), P(), P()),
            manual_axes={"data"},
        ),
        in_shardings=(
            jax.tree.map(lambda _: repl, params),
            jax.tree.map(lambda _: repl, opt),
            {"tokens": dsh, "labels": dsh, "mask": dsh},
        ),
        out_shardings=(
            jax.tree.map(lambda _: repl, params),
            jax.tree.map(lambda _: repl, opt),
            repl,
        ),
    )

    print(f"data-parallel training on {n_dev} devices, COUNTDOWN Slack live:")
    with set_mesh(mesh):
        for i in range(30):
            batch = make_batch(cfg, batch=8, seq_len=33, seed=i, kind="train")
            batch = {k: jax.device_put(v, dsh) for k, v in batch.items()}
            params, opt, loss = step(params, opt, batch)
            jax.block_until_ready(loss)
            if i % 10 == 0 or i == 29:
                print(f"  step {i:3d}  loss {float(loss):.3f}")

    rep = governor.finalize()
    print("\nGovernor report (reconstructed from live phase events):")
    print(f"  instrumented collectives : {rep.n_calls}")
    print(f"  total slack observed     : {rep.total_slack*1e3:.2f} ms")
    print(f"  timeout downshifts       : {rep.n_downshifts}")
    print(f"  exploitable slack        : {rep.exploited_slack*1e3:.2f} ms")
    print(f"  est. energy saving (comm): {rep.energy_saving_pct:.2f}%")
    print(f"  P-state actuations logged: {len(governor.actuation_log)}")
    if rep.stragglers:
        print(f"  stragglers flagged       : {rep.stragglers}")
    else:
        print("  stragglers flagged       : none (balanced ranks)")

    instrument.set_mode("off")
    instrument.enable_events(False)
    instrument.get_event_bus().unsubscribe(governor)


if __name__ == "__main__":
    main()
