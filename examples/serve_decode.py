"""Batched serving: prefill a prompt batch, decode continuations with the
KV/recurrent caches, compare a windowed-attention arch vs an SSM — then
run the same model under continuous batching with a paged KV pool and a
governor pricing decode underfill like MPI slack.

  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core.governor import Governor
from repro.models import init_params
from repro.models.inputs import make_batch
from repro.serve import (
    ContinuousEngine,
    Request,
    ServeEngine,
    SLOTracker,
    poisson_arrivals,
)


def demo(arch: str, n_steps: int = 16) -> None:
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=128, temperature=0.8)
    batch = make_batch(cfg, batch=4, seq_len=32, kind="prefill")
    t0 = time.time()
    out = eng.generate(batch, n_steps=n_steps, key=jax.random.PRNGKey(7))
    dt = time.time() - t0
    print(f"{arch:20s} generated {out.shape} tokens in {dt:.2f}s "
          f"({4 * n_steps / dt:.1f} tok/s incl. compile)")
    print(f"  sample: {out[0].tolist()}")


def demo_continuous(arch: str = "llama3.2-1b", n_requests: int = 8) -> None:
    """Poisson arrivals through the paged continuous engine + governor."""
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ContinuousEngine(cfg, params, n_slots=4, max_len=64, page=8)
    eng.generate(make_batch(cfg, batch=1, seq_len=16, kind="prefill"),
                 n_steps=4)                        # warmup/compile
    rng = np.random.default_rng(0)
    arrivals = poisson_arrivals(n_requests, rate=40.0, seed=0,
                                burst_every=4, burst_gap=0.05)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab, size=16).astype(np.int32),
                max_new=int(rng.integers(3, 13)), arrival=float(arrivals[i]))
        for i in range(n_requests)
    ]
    gov, slo = Governor(), SLOTracker()
    t0 = time.time()
    done = eng.serve(reqs, governor=gov, slo=slo)
    dt = time.time() - t0
    rep = gov.finalize()
    n_tok = sum(len(r.out) for r in done)
    print(f"{arch:20s} continuous: {n_tok} tokens / {len(done)} requests in "
          f"{dt:.2f}s ({n_tok / dt:.1f} tok/s, fill "
          f"{eng._last_meter.fill_fraction:.2f})")
    print(f"  decode slack priced: {rep.total_slack * 1e3:.1f} ms, "
          f"{rep.n_downshifts} downshifts, saving {rep.energy_saving_pct:.1f}%; "
          f"TTFT p95 {slo.summary()['ttft']['p95'] * 1e3:.1f} ms")


def main() -> None:
    print("batched generation across architecture families:")
    demo("llama3.2-1b")          # dense GQA, linear KV cache
    demo("mixtral-8x22b")        # MoE + sliding-window ring cache
    demo("mamba2-130m")          # attention-free: O(1) recurrent state
    demo("recurrentgemma-2b")    # hybrid RG-LRU + local attention
    print("\ncontinuous batching with paged KV + governor-priced slack:")
    demo_continuous()


if __name__ == "__main__":
    main()
