"""Batched serving: prefill a prompt batch, decode continuations with the
KV/recurrent caches, compare a windowed-attention arch vs an SSM.

  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax

from repro.configs import get_config, reduced
from repro.models import init_params
from repro.models.inputs import make_batch
from repro.serve.engine import ServeEngine


def demo(arch: str, n_steps: int = 16) -> None:
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=128, temperature=0.8)
    batch = make_batch(cfg, batch=4, seq_len=32, kind="prefill")
    t0 = time.time()
    out = eng.generate(batch, n_steps=n_steps, key=jax.random.PRNGKey(7))
    dt = time.time() - t0
    print(f"{arch:20s} generated {out.shape} tokens in {dt:.2f}s "
          f"({4 * n_steps / dt:.1f} tok/s incl. compile)")
    print(f"  sample: {out[0].tolist()}")


def main() -> None:
    print("batched generation across architecture families:")
    demo("llama3.2-1b")          # dense GQA, linear KV cache
    demo("mixtral-8x22b")        # MoE + sliding-window ring cache
    demo("mamba2-130m")          # attention-free: O(1) recurrent state
    demo("recurrentgemma-2b")    # hybrid RG-LRU + local attention


if __name__ == "__main__":
    main()
