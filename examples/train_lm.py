"""End-to-end driver: train the ~100M-parameter LM on the synthetic corpus.

  PYTHONPATH=src python examples/train_lm.py --steps 300

This is the paper-scale end-to-end example (deliverable b): real data
pipeline with host prefetch, AdamW with warmup+cosine, checkpointing, and a
live loss curve.  On this 1-core container a full step of the 100M model
takes ~O(1 min); pass ``--preset small`` for a fast local run of the same
code path.
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.dist.checkpoint import CheckpointManager
from repro.train.data import DataLoader
from repro.train.loop import init_state, make_train_step
from repro.train.optimizer import OptConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--preset", choices=["100m", "small"], default="small")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_config("countdown-100m")
    if args.preset == "small":
        cfg = reduced(cfg, n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
                      d_ff=1024, vocab=4096)
        args.seq = min(args.seq, 128)
    opt_cfg = OptConfig(lr=6e-4, warmup_steps=max(10, args.steps // 20),
                        total_steps=args.steps)
    state = init_state(cfg, opt_cfg, jax.random.PRNGKey(0))
    n_params = sum(int(x.size) for x in jax.tree.leaves(state["params"]))
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params), "
          f"batch {args.batch} x seq {args.seq}")

    step = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0,))
    loader = DataLoader(cfg, batch=args.batch, seq_len=args.seq)
    mgr = CheckpointManager(args.checkpoint_dir, keep=2, async_save=True)
    losses = []
    for i, batch in zip(range(args.steps), loader):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
        if (i + 1) % max(1, args.steps // 25) == 0:
            print(f"step {i+1:4d}  loss {losses[-1]:.4f}  "
                  f"(avg10 {np.mean(losses[-10:]):.4f})  lr {float(m['lr']):.2e}",
                  flush=True)
        if (i + 1) % 100 == 0:
            mgr.save(i + 1, jax.device_get(state))
    mgr.wait()
    loader.close()
    print(f"\nloss: {np.mean(losses[:10]):.3f} -> {np.mean(losses[-10:]):.3f} "
          f"over {args.steps} steps")


if __name__ == "__main__":
    main()
