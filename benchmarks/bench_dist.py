"""Distribution-substrate micro-benchmarks.

  compressed_psum vs raw psum   — step latency of the int8 cross-pod codec
                                  against the uncompressed reduction, plus
                                  the wire-bytes ratio it buys.
  StragglerDetector throughput  — observe_barrier calls/s at fleet sizes
                                  from 8 to 1024 ranks (the governor calls
                                  this once per reconstructed collective, so
                                  it must stay far off the step critical
                                  path).

Emits the standard ``name,us_per_call,derived`` CSV contract.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json, time_call


def _bench_compressed_psum(full: bool) -> dict:
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.dist.compat import shard_map
    from repro.dist.compression import compressed_psum, compression_ratio

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",))
    sizes = [1 << 16, 1 << 20] + ([1 << 22] if full else [])
    results = {}
    for size in sizes:
        grads = {"g": jnp.asarray(np.random.default_rng(0).normal(size=size), jnp.float32)}

        def reduce_with(fn):
            return jax.jit(
                shard_map(
                    fn, mesh=mesh, in_specs=(P(),), out_specs=P(),
                    manual_axes={"data"},
                )
            )

        raw = reduce_with(lambda g: jax.tree.map(lambda a: jax.lax.psum(a, "data"), g))
        comp = reduce_with(lambda g: compressed_psum(g, "data"))
        jax.block_until_ready(raw(grads))            # compile outside timing
        jax.block_until_ready(comp(grads))
        us_raw, _ = time_call(lambda: jax.block_until_ready(raw(grads)), repeats=5)
        us_comp, _ = time_call(lambda: jax.block_until_ready(comp(grads)), repeats=5)
        ratio = compression_ratio(grads)
        emit(f"dist.psum_raw.{size}", us_raw, f"devices={n_dev}")
        emit(f"dist.psum_int8.{size}", us_comp, f"wire_ratio={ratio:.2f}x")
        results[size] = {
            "us_raw": us_raw, "us_int8": us_comp, "wire_ratio": ratio,
        }
    return results


def _bench_straggler(full: bool) -> dict:
    from repro.dist.straggler import StragglerDetector

    rng = np.random.default_rng(0)
    results = {}
    for n_ranks in [8, 64, 1024] if full else [8, 64]:
        det = StragglerDetector()
        barriers = [
            {r: float(t) for r, t in enumerate(rng.normal(0, 1e-3, n_ranks))}
            for _ in range(64)
        ]

        def run():
            for b in barriers:
                det.observe_barrier(b)
            return det.stragglers()

        us, _ = time_call(run, repeats=5)
        per_call = us / len(barriers)
        emit(f"dist.straggler_observe.{n_ranks}r", per_call,
             f"{1e6 / max(per_call, 1e-9):.0f}calls_per_s")
        results[n_ranks] = {"us_per_observe": per_call}
    return results


def run(full: bool = False) -> None:
    payload = {
        "compressed_psum": _bench_compressed_psum(full),
        "straggler": _bench_straggler(full),
    }
    save_json("bench_dist", payload)


if __name__ == "__main__":
    run()
