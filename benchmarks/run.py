"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (harness contract) and writes
full JSON artifacts under artifacts/.

  table1  — predictability SMAPE (paper Table 1)
  table2  — slack-isolation potential (paper Table 2)
  table3  — overhead / energy / power per policy x app (paper Table 3)
  fig3    — permutation feature importance (paper Fig. 3)
  roofline— 3-term roofline per (arch x shape x mesh) from dry-run artifacts
  runtime — framework micro-benchmarks (simulator/governor/barrier cost)
  dist    — distribution substrate (int8 compressed_psum, straggler detector)
  serve   — static vs continuous vs continuous+pallas tok/s + priced decode slack
  fleet   — static-N vs autoscaled replica fleet: joules/token, SLO
            attainment, prefix-cache hit rate under the cluster watt cap
  cluster — slack-driven cap arbiter vs static equal-split + trace replay

``python -m benchmarks.run [--only table3,roofline] [--full]``
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default="", help="comma-separated subset")
    ap.add_argument("--full", action="store_true", help="slow full versions")
    args = ap.parse_args()

    from benchmarks import (
        bench_cluster,
        bench_dist,
        bench_runtime,
        bench_serve,
        fig3_feature_importance,
        roofline,
        table1_predictability,
        table2_slack_isolation,
        table3_runtime_comparison,
    )

    suites = {
        "table2": table2_slack_isolation.run,
        "table3": table3_runtime_comparison.run,
        "runtime": bench_runtime.run,
        "dist": bench_dist.run,
        "serve": bench_serve.run,
        "fleet": bench_serve.run_fleet,
        "cluster": bench_cluster.run,
        "table1": table1_predictability.run,
        "fig3": fig3_feature_importance.run,
        "roofline": roofline.run,
    }
    selected = [s.strip() for s in args.only.split(",") if s.strip()] or list(suites)
    print("name,us_per_call,derived")
    t0 = time.time()
    failures = 0
    for name in selected:
        if name not in suites:
            print(f"{name},0.0,UNKNOWN-SUITE", flush=True)
            failures += 1
            continue
        try:
            suites[name](full=args.full)
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}", flush=True)
            failures += 1
    print(f"total,{(time.time() - t0) * 1e6:.0f},suites={len(selected)};failures={failures}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
