"""Paper Table 3: execution-time overhead, energy saving, power saving of
every policy vs the Baseline, per application + averages/worst cases."""
from __future__ import annotations

import numpy as np

from benchmarks.common import baseline_trace, emit, save_json, time_call
from repro.core.policies import ALL_POLICIES
from repro.core.simulator import simulate
from repro.core.workloads import APPS

POLICIES = [
    "minfreq", "fermata_100ms", "fermata_500us", "andante", "adagio",
    "countdown", "cntd_slack",
]

# Paper Table 3 averages (ovh / esave / psave) for context
PAPER_AVG = {
    "minfreq": (55.14, 8.56, 36.35),
    "fermata_100ms": (3.19, 11.07, 14.25),
    "andante": (38.65, 5.45, 25.82),
    "adagio": (42.87, 5.46, 27.53),
    "countdown": (4.02, 15.28, 19.24),
    "cntd_slack": (0.79, 9.96, 10.73),
}


def run(full: bool = True) -> dict:
    table: dict = {"apps": {}, "avg": {}, "worst": {}, "paper_avg": PAPER_AVG}
    acc = {p: [] for p in POLICIES}
    for app in APPS:
        wl, base, _ = baseline_trace(app)
        row = {}
        for pol in POLICIES:
            us, res = time_call(lambda p=pol: simulate(wl, ALL_POLICIES[p])[0], repeats=1)
            cell = {
                "overhead_pct": res.overhead_vs(base),
                "energy_saving_pct": res.energy_saving_vs(base),
                "power_saving_pct": res.power_saving_vs(base),
            }
            row[pol] = cell
            acc[pol].append(cell)
            emit(
                f"table3/{app}/{pol}", us,
                f"ovh={cell['overhead_pct']:.2f};esave={cell['energy_saving_pct']:.2f}",
            )
        table["apps"][app] = row
    for pol in POLICIES:
        cells = acc[pol]
        table["avg"][pol] = {
            k: float(np.mean([c[k] for c in cells])) for k in cells[0]
        }
        table["worst"][pol] = {
            "overhead_pct": float(max(c["overhead_pct"] for c in cells)),
            "energy_saving_pct": float(min(c["energy_saving_pct"] for c in cells)),
        }
        emit(
            f"table3/AVG/{pol}", 0.0,
            "ovh={overhead_pct:.2f};esave={energy_saving_pct:.2f};psave={power_saving_pct:.2f}".format(
                **table["avg"][pol]
            ),
        )
    # the predictive axis: prediction-only strawman vs fixed / adaptive /
    # guarded hybrid on the three golden stream families (DESIGN.md §12)
    from benchmarks.bench_runtime import table3 as predictive_table3

    table["predictive"] = predictive_table3()
    save_json("table3_runtime_comparison", table)
    return table


if __name__ == "__main__":
    run()
