"""Micro-benchmarks of the framework's own moving parts: simulator
throughput, governor event ingestion, kernel interpret-mode sanity, and the
instrumentation overhead of the artificial barrier (paper §4.2 claim:
negligible)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import baseline_trace, emit, time_call
from repro.core.governor import Governor
from repro.core.policies import ALL_POLICIES, BASELINE, COUNTDOWN_SLACK
from repro.core.simulator import simulate
from repro.core.workloads import APPS, generate


def run(full: bool = False) -> dict:
    out = {}

    # simulator throughput (rank-task events / s)
    wl, _, _ = baseline_trace("nas_is.D.128")
    us, _ = time_call(lambda: simulate(wl, COUNTDOWN_SLACK)[0], repeats=2)
    events = wl.n_tasks * wl.n_ranks
    out["sim_events_per_s"] = events / (us / 1e6)
    emit("bench/simulator", us, f"events_per_s={out['sim_events_per_s']:.0f}")

    # governor ingestion rate
    gov = Governor()
    n_calls, n_ranks = 2000, 16
    t0 = time.perf_counter()
    for c in range(n_calls):
        for r in range(n_ranks):
            gov.sink(r, "barrier_enter", c, c * 1e-3)
            gov.sink(r, "barrier_exit", c, c * 1e-3 + 5e-4)
            gov.sink(r, "copy_exit", c, c * 1e-3 + 7e-4)
    dt = time.perf_counter() - t0
    rep = gov.finalize()
    out["governor_events_per_s"] = 3 * n_calls * n_ranks / dt
    emit("bench/governor", dt * 1e6, f"events_per_s={out['governor_events_per_s']:.0f}")

    # artificial-barrier cost inside the simulator (paper: negligible)
    base, _ = simulate(wl, BASELINE)
    res, _ = simulate(wl, ALL_POLICIES["cntd_slack"])
    out["barrier_overhead_pct"] = res.overhead_vs(base)
    emit("bench/barrier_overhead", 0.0, out["barrier_overhead_pct"])

    if full:
        import jax.numpy as jnp

        from repro.kernels import ops

        x = jnp.ones((64, 256), jnp.float32)
        sc = jnp.ones((256,), jnp.float32)
        ops.rmsnorm(x, sc).block_until_ready()
        us, _ = time_call(lambda: ops.rmsnorm(x, sc).block_until_ready(), repeats=3)
        emit("bench/rmsnorm_interpret", us, "interpret-mode (CPU)")
    return out


if __name__ == "__main__":
    run(full=True)
