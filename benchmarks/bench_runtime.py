"""Micro-benchmarks of the framework's own moving parts: simulator
throughput, governor sink throughput (events/sec through the streaming
engine — the number the bounded-RSS refactor is held to), kernel
interpret-mode sanity, the instrumentation overhead of the artificial
barrier (paper §4.2 claim: negligible), and the theta sweep — adaptive
theta (cntd_adaptive) vs the paper's fixed 500 us across the three
co-scheduling workload families (compute-bound / comm-bound / bursty).

``python benchmarks/bench_runtime.py sink_throughput`` runs just the
governor hot-path benchmark; ``... telemetry_overhead [--check]`` runs the
obs-stack overhead guard (``--check`` exits non-zero past the 10% budget).
"""
from __future__ import annotations

import dataclasses
import sys
import time

import numpy as np

from benchmarks.common import baseline_trace, emit, save_json, time_call
from repro.core.governor import Governor
from repro.core.policies import ALL_POLICIES, BASELINE, CNTD_ADAPTIVE, COUNTDOWN_SLACK
from repro.core.simulator import simulate
from repro.core.workloads import APPS, generate

THETA_GRID = (250e-6, 500e-6, 1e-3, 2e-3)
FAMILIES = ("compute_bound", "comm_bound", "bursty_serve")

# Table-3 predictive contrast: the ladder from prediction-only (Guermouche /
# Fermata-style whole-comm pre-arm, no fallback) through the paper's fixed
# 500 us and the adaptive tuner to the guarded hybrid
TABLE3_POLICIES = ("cntd_predict_only", "cntd_slack", "cntd_adaptive",
                   "cntd_predictive")
TABLE3_BUDGET_PCT = 1.0          # the paper's rho: 1% time-overhead budget
TABLE3_N_TASKS = 1000            # long enough that predictor warmup washes out


DEFAULT_CHUNK = 65536        # instrument.DEFAULT_BATCH_SIZE: the fold's sweet spot


def _stream_columns(n_calls: int, n_ranks: int, call_base: int = 0):
    """The sink benchmark's event stream as fixed-dtype columns.

    Exactly the sequence the per-event loop produces — per call: all
    barrier_enters (skewed 1 us/rank), then per rank barrier_exit +
    copy_exit — so the two arms fold the identical stream.  Call ids
    recur mod 50 (the rotation path); ``call_base`` offsets call index
    and time so windows chain into one long stream.
    """
    R = n_ranks
    ranks_blk = np.concatenate(
        [np.arange(R, dtype=np.int32), np.repeat(np.arange(R, dtype=np.int32), 2)])
    codes_blk = np.concatenate(
        [np.zeros(R, dtype=np.int8), np.tile(np.array([1, 2], dtype=np.int8), R)])
    t_blk = np.concatenate(
        [np.arange(R) * 1e-6, np.tile(np.array([1e-3, 1.2e-3]), R)])
    n_blk = 3 * R
    c = np.arange(call_base, call_base + n_calls, dtype=np.int64)
    ranks = np.tile(ranks_blk, n_calls)
    codes = np.tile(codes_blk, n_calls)
    cids = np.repeat(c % 50, n_blk)
    # per-call base times as the sequential fold the per-event loop's
    # ``t += 2e-3`` performs (np.add.accumulate is a strict left fold),
    # so the two arms' float streams are bitwise identical
    step = np.full(n_calls, 2e-3)
    step[0] = 0.0
    t_call = np.add.accumulate(step)
    if call_base:
        t_call += call_base * 2e-3
    ts = np.tile(t_blk, n_calls) + np.repeat(t_call, n_blk)
    return ranks, codes, cids, ts


def _stream_batches(cols, chunk: int = DEFAULT_CHUNK) -> list:
    from repro.core.events import EventBatch

    ranks, codes, cids, ts = cols
    n = ranks.shape[0]
    return [EventBatch(ranks[i:i + chunk], codes[i:i + chunk],
                       cids[i:i + chunk], ts[i:i + chunk], capacity=chunk)
            for i in range(0, n, chunk)]


def sink_throughput(n_calls: int = 12000, n_ranks: int = 16,
                    repeats: int = 9, chunk: int = DEFAULT_CHUNK) -> dict:
    """Events/sec through the full ingest pipeline (producer -> EventBus
    -> governor) on a downshift-heavy stream, A/B: per-event
    ``EventBus.publish`` vs ``EventBus.publish_batch`` over the identical
    stream (recurring call ids — every occurrence rotates through
    retirement + streaming accumulation; 1 ms slack over the 500 us
    default theta — every barrier_exit books an actuation pair).

    The arms are interleaved (A,B,A,B,...) and compared on per-arm
    medians so ambient load lands on both, and the per-event baseline the
    speedup is quoted against comes from the same run.  Also reported:
    bitwise equality of the two arms' ``GovernorReport``s (the batched
    fold's contract), finalize() wall time (must stay flat — an
    O(in-flight) read of the accumulators), and the retained-record count
    (bounded by the retention ring, not the stream length).

    Acceptance (CI ``--check``): batched median >= 5M ev/s and >= 8x the
    per-event median.
    """
    from repro.core.events import EventBus

    n_events = 3 * n_calls * n_ranks

    def stream_events(gov: Governor) -> float:
        bus = EventBus()
        bus.subscribe(gov)
        pub = bus.publish
        t0 = time.perf_counter()
        t = 0.0
        for c in range(n_calls):
            cid = c % 50                    # call ids recur: rotation path
            for r in range(n_ranks):
                pub(r, "barrier_enter", cid, t + r * 1e-6)
            for r in range(n_ranks):
                pub(r, "barrier_exit", cid, t + 1e-3)
                pub(r, "copy_exit", cid, t + 1.2e-3)
            t += 2e-3
        return n_events / (time.perf_counter() - t0)

    batches = _stream_batches(_stream_columns(n_calls, n_ranks), chunk)

    def stream_batched(gov: Governor) -> float:
        bus = EventBus()
        bus.subscribe(gov)
        pub = bus.publish_batch
        t0 = time.perf_counter()
        for b in batches:
            pub(b)
        return n_events / (time.perf_counter() - t0)

    rates_a, rates_b = [], []
    gov_a = gov_b = None
    for _ in range(repeats):
        gov_a = Governor()
        rates_a.append(stream_events(gov_a))
        gov_b = Governor()
        rates_b.append(stream_batched(gov_b))
    med_a = float(np.median(rates_a))
    med_b = float(np.median(rates_b))
    rep_a = gov_a.finalize()
    t0 = time.perf_counter()
    rep_b = gov_b.finalize()
    t_fin = time.perf_counter() - t0
    out = {
        "events_per_s": med_b,
        "per_event_events_per_s": med_a,
        "speedup": med_b / med_a,
        "batched_min_events_per_s": float(min(rates_b)),
        "n_events": n_events,
        "chunk": chunk,
        "reports_equal": rep_a.to_dict() == rep_b.to_dict(),
        "finalize_s": t_fin,
        "n_retained": len(gov_b.recent_records()),
        "n_calls": rep_b.n_calls,
    }
    emit("bench/sink_throughput", 1e6 / med_b,
         f"events_per_s={med_b:.0f};per_event={med_a:.0f};"
         f"speedup={out['speedup']:.2f};finalize_s={t_fin:.4f};"
         f"retained={out['n_retained']};equal={out['reports_equal']}")
    return out


def telemetry_overhead(n_calls: int = 2500, n_ranks: int = 16,
                       repeats: int = 7) -> dict:
    """The obs-stack overhead guard: ``sink_throughput``'s event stream
    through an :class:`~repro.core.events.EventBus` with (A) only the
    governor subscribed (the bare-bus baseline) vs (B) the full telemetry
    stack attached the way the launch drivers wire it — a
    :class:`~repro.obs.tracer.GovernorTap` in the governor's recorder slot
    forwarding retired occurrences and theta decisions to a
    :class:`~repro.obs.tracer.SpanTracer` and a
    :class:`~repro.obs.metrics.BusMetrics`, plus the cold-path costs the
    report cadence pays (a registry snapshot and the spine-log actuation
    pull).

    Both ingest paths are guarded: the per-event pair streams through
    ``EventBus.publish``, the batched pair streams the identical columns
    through ``EventBus.publish_batch`` (the tap advertises
    ``on_retired_batch``, so the governor keeps its vectorized fold while
    recording).  All four arms are interleaved (A,B,C,D,...) and compared
    on per-arm medians, so ambient load lands on every arm instead of
    whichever ran last.  The acceptance bar (CI ``--check``): attached
    within 10% of bare on *each* path (``ratio >= 0.9``).
    """
    from repro.core.events import EventBus
    from repro.obs.metrics import BusMetrics, MetricsRegistry
    from repro.obs.tracer import GovernorTap, SpanTracer

    n_events = 3 * n_calls * n_ranks
    batches = _stream_batches(_stream_columns(n_calls, n_ranks))

    def stream(bus: EventBus) -> float:
        t0 = time.perf_counter()
        t = 0.0
        for c in range(n_calls):
            cid = c % 50
            for r in range(n_ranks):
                bus.publish(r, "barrier_enter", cid, t + r * 1e-6)
            for r in range(n_ranks):
                bus.publish(r, "barrier_exit", cid, t + 1e-3)
                bus.publish(r, "copy_exit", cid, t + 1.2e-3)
            t += 2e-3
        return n_events / (time.perf_counter() - t0)

    def stream_batched(bus: EventBus) -> float:
        t0 = time.perf_counter()
        for b in batches:
            bus.publish_batch(b)
        return n_events / (time.perf_counter() - t0)

    def bare(streamer) -> float:
        bus = EventBus()
        bus.subscribe(Governor())
        return streamer(bus)

    def attached(streamer) -> float:
        registry = MetricsRegistry()
        tracer = SpanTracer()
        tap = GovernorTap(tracer, metrics=BusMetrics(registry))
        gov = Governor(recorder=tap)
        bus = EventBus()
        bus.subscribe(gov)
        rate = streamer(bus)
        registry.snapshot()             # include the collector-sync cost
        tracer.ingest_governor(gov)     # ... and the export-time spine pull
        return rate

    rates: dict = {"bare": [], "attached": [],
                   "bare_batched": [], "attached_batched": []}
    for _ in range(repeats):
        rates["bare"].append(bare(stream))
        rates["attached"].append(attached(stream))
        rates["bare_batched"].append(bare(stream_batched))
        rates["attached_batched"].append(attached(stream_batched))
    med = {k: float(np.median(v)) for k, v in rates.items()}
    out = {
        "bare_events_per_s": med["bare"],
        "telemetry_events_per_s": med["attached"],
        "ratio": med["attached"] / med["bare"],
        "overhead_pct": 100.0 * (1.0 - med["attached"] / med["bare"]),
        "batched_bare_events_per_s": med["bare_batched"],
        "batched_telemetry_events_per_s": med["attached_batched"],
        "batched_ratio": med["attached_batched"] / med["bare_batched"],
        "batched_overhead_pct":
            100.0 * (1.0 - med["attached_batched"] / med["bare_batched"]),
        "n_events": n_events,
        "repeats": repeats,
    }
    emit("bench/telemetry_overhead", 1e6 / med["attached"],
         f"bare={med['bare']:.0f};telemetry={med['attached']:.0f};"
         f"ratio={out['ratio']:.3f};batched_ratio={out['batched_ratio']:.3f}")
    return out


def ingest_soak(n_events: int = 10_000_000, n_ranks: int = 64,
                chunk: int = DEFAULT_CHUNK, window_calls: int = 2000,
                rss_budget_mb: float = 256.0) -> dict:
    """Long-horizon batched-ingest soak: a continuous 64-rank stream is
    generated window-by-window (so the producer itself is O(window), like
    a real run), published through ``EventBus.publish_batch`` into the
    production recorder wiring (GovernorTap -> BusMetrics), and held to a
    bounded-RSS contract: after the first window warms every pool (numpy
    buffers, retention ring, accumulators), the process high-water mark
    may grow by at most ``rss_budget_mb`` regardless of stream length —
    the week-long-trace property.  RSS is read from
    ``resource.getrusage`` (ru_maxrss), events/s over the whole soak, and
    the bus's own ingest counters cross-check delivery.
    """
    import resource

    from repro.core.events import EventBus
    from repro.obs.metrics import BusMetrics, IngestMetrics, MetricsRegistry
    from repro.obs.tracer import GovernorTap

    def rss_mb() -> float:
        return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0

    registry = MetricsRegistry()
    tap = GovernorTap(None, metrics=BusMetrics(registry))
    # log_retention bounds the raw actuation spine — without it the spine
    # is an unbounded debugging log and a week-long stream grows without
    # limit no matter how tight the rest of the pipeline is
    gov = Governor(recorder=tap, log_retention=1024)
    bus = EventBus()
    bus.subscribe(gov)
    ingest = IngestMetrics(registry, bus)

    ev_per_call = 3 * n_ranks
    n_calls = max(1, n_events // ev_per_call)
    published = 0
    call_base = 0
    rss_warm = None
    t0 = time.perf_counter()
    while call_base < n_calls:
        wc = min(window_calls, n_calls - call_base)
        for b in _stream_batches(_stream_columns(wc, n_ranks, call_base), chunk):
            bus.publish_batch(b)
            published += b.n
        call_base += wc
        if rss_warm is None:
            rss_warm = rss_mb()
    dt = time.perf_counter() - t0
    rep = gov.finalize()
    st = ingest.collect()
    rss_final = rss_mb()
    out = {
        "events_per_s": published / dt,
        "n_events": published,
        "wall_s": dt,
        "n_ranks": n_ranks,
        "rss_warm_mb": rss_warm,
        "rss_final_mb": rss_final,
        "rss_growth_mb": rss_final - rss_warm,
        "rss_budget_mb": rss_budget_mb,
        "rss_ok": rss_final - rss_warm <= rss_budget_mb,
        "delivered_ok": int(st["events_total"]) == published,
        "n_retained": len(gov.recent_records()),
        "n_calls": rep.n_calls,
        "mean_occupancy": st["mean_occupancy"],
    }
    emit("bench/ingest_soak", 1e6 * dt / max(published, 1),
         f"events_per_s={out['events_per_s']:.0f};n={published};"
         f"rss_growth_mb={out['rss_growth_mb']:.1f};"
         f"retained={out['n_retained']}")
    return out


def device_producer_smoke(n_iters: int = 4) -> dict:
    """64-emulated-rank stress of the jitted producer path: under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=64`` an
    instrumented ``cd_psum`` runs in a shard_map over every device with
    batched ingestion on, so each collective's 3-phase events cross the
    io_callback wire into the BatchAccumulator; ``flush_events`` then
    drains the partial chunk through the bus.  Verifies the full
    device->accumulator->bus->governor spine end to end (every event
    delivered, none dropped to the per-event fallback).
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core import instrument
    from repro.dist.compat import set_mesh, shard_map

    n_dev = len(jax.devices())
    gov = Governor()
    instrument.reset_instrumentation()
    instrument.set_mode("profile")
    instrument.enable_events(True)
    instrument.set_ingest_mode("batched")
    bus = instrument.get_event_bus()
    bus.subscribe(gov)
    try:
        mesh = jax.make_mesh((n_dev,), ("r",))
        from repro.core.instrument import cd_psum

        def f(x):
            return cd_psum(x, "r")

        with set_mesh(mesh):
            g = jax.jit(shard_map(f, mesh=mesh, in_specs=P("r"),
                                  out_specs=P("r"), manual_axes=("r",)))
            x = jnp.arange(float(n_dev))
            for _ in range(n_iters):
                jax.block_until_ready(g(x))
        instrument.flush_events()
        st = bus.ingest_stats()
        rep = gov.finalize()
    finally:
        instrument.reset_instrumentation()
    expected = 3 * n_dev * n_iters
    out = {
        "n_devices": n_dev,
        "n_events_expected": expected,
        "n_events_ingested": int(st["events_total"]),
        "fallback_events": int(st["fallback_events_total"]),
        "n_calls": rep.n_calls,
        "ok": int(st["events_total"]) == expected
              and int(st["fallback_events_total"]) == 0
              and rep.n_calls == n_iters,
    }
    emit("bench/device_producer", 0.0,
         f"devices={n_dev};events={out['n_events_ingested']}/{expected};"
         f"calls={rep.n_calls};ok={out['ok']}")
    return out


def theta_sweep(seed: int = 0, n_tasks: int = 400) -> dict:
    """Adaptive vs fixed theta on the three tenant families (DESIGN.md §8).

    For each family: baseline, fixed-theta cntd_slack across ``THETA_GRID``,
    and ``cntd_adaptive`` (online ThetaTuner).  Reports energy saving and
    time-to-completion overhead vs baseline, plus the two acceptance
    aggregates: adaptive beats (or matches) fixed-500us on >= 1 family, and
    adaptive overhead stays under 1% on every family.
    """
    from repro.cluster.coschedule import MIX_SPECS

    out: dict = {"families": {}}
    beats = False
    max_ovh = 0.0
    for fam in FAMILIES:
        spec = dataclasses.replace(MIX_SPECS[fam], n_tasks=n_tasks)
        wl = generate(spec, seed=seed)
        base, _ = simulate(wl, BASELINE)
        row: dict = {}
        for th in THETA_GRID:
            pol = dataclasses.replace(COUNTDOWN_SLACK, theta=th)
            res, _ = simulate(wl, pol)
            row[f"fixed_{th * 1e6:.0f}us"] = {
                "energy_saving_pct": res.energy_saving_vs(base),
                "overhead_pct": res.overhead_vs(base),
            }
        us, ad = time_call(lambda: simulate(wl, CNTD_ADAPTIVE)[0], repeats=1)
        row["adaptive"] = {
            "energy_saving_pct": ad.energy_saving_vs(base),
            "overhead_pct": ad.overhead_vs(base),
            "theta_eff_final_us": float(np.nanmean(ad.theta_series[-20:]) * 1e6),
        }
        out["families"][fam] = row
        fixed500 = row["fixed_500us"]["energy_saving_pct"]
        adaptive = row["adaptive"]["energy_saving_pct"]
        beats = beats or adaptive >= fixed500
        max_ovh = max(max_ovh, row["adaptive"]["overhead_pct"])
        emit(
            f"bench/theta_sweep/{fam}", us,
            f"esave_fixed500={fixed500:.2f};esave_adaptive={adaptive:.2f};"
            f"ovh_adaptive={row['adaptive']['overhead_pct']:.3f}",
        )
    out["adaptive_beats_fixed500"] = bool(beats)
    out["max_overhead_pct"] = float(max_ovh)
    save_json("theta_sweep", out)
    return out


def table3(seed: int = 0, n_tasks: int = TABLE3_N_TASKS) -> dict:
    """Paper Table 3 on the predictive axis (DESIGN.md §12): prediction-only
    vs fixed-500us vs cntd_adaptive vs the guarded hybrid on the three
    golden stream families.

    ``cntd_predict_only`` is the prediction-based strawman (Guermouche /
    Fermata lineage): it pre-arms the downshift at comm entry on ANY
    predicted slack and slows the whole call — slack *and* copy — with no
    reactive fallback and no guard.  ``cntd_predictive`` is the hybrid:
    pre-arm only past the residue-cost bar, reactive ThetaTuner fallback
    otherwise, per-site misprediction guard tripping back to the pure tuner.

    Reported per family: energy saving / wall overhead / DVFS busy-time cost
    (the quantity the 1% rho budget actually constrains), pre-arm, mispredict
    and guard-trip counts.  Acceptance aggregates (CI ``--check``):

    * ``prediction_only_exceeds_budget`` — the strawman's wall overhead
      blows the 1% budget on >= 1 family (it does on all three);
    * ``hybrid_within_budget`` — the hybrid stays <= 1% on every family;
    * ``hybrid_beats_adaptive_everywhere`` — hybrid energy saving >=
      cntd_adaptive on every family.
    """
    from repro.cluster.coschedule import MIX_SPECS

    out: dict = {
        "seed": seed, "n_tasks": n_tasks,
        "overhead_budget_pct": TABLE3_BUDGET_PCT, "families": {},
    }
    po_exceeds, hy_within, hy_beats = False, True, True
    for fam in FAMILIES:
        spec = dataclasses.replace(MIX_SPECS[fam], n_tasks=n_tasks)
        wl = generate(spec, seed=seed)
        base, _ = simulate(wl, BASELINE)
        row: dict = {}
        for name in TABLE3_POLICIES:
            us, res = time_call(
                lambda p=name: simulate(wl, ALL_POLICIES[p])[0], repeats=1)
            row[name] = {
                "energy_saving_pct": res.energy_saving_vs(base),
                "overhead_pct": res.overhead_vs(base),
                "dvfs_cost_pct": res.dvfs_cost_pct(),
                "n_prearm": res.n_prearm,
                "n_mispredict": res.n_mispredict,
                "n_guard_trips": res.n_guard_trips,
            }
            emit(
                f"bench/table3/{fam}/{name}", us,
                f"esave={row[name]['energy_saving_pct']:.2f};"
                f"ovh={row[name]['overhead_pct']:.3f};"
                f"dvfs={row[name]['dvfs_cost_pct']:.3f};"
                f"prearm={res.n_prearm};mis={res.n_mispredict};"
                f"trips={res.n_guard_trips}",
            )
        out["families"][fam] = row
        po, hy, ad = (row["cntd_predict_only"], row["cntd_predictive"],
                      row["cntd_adaptive"])
        po_exceeds = po_exceeds or po["overhead_pct"] > TABLE3_BUDGET_PCT
        hy_within = hy_within and hy["overhead_pct"] <= TABLE3_BUDGET_PCT
        hy_beats = hy_beats and (
            hy["energy_saving_pct"] >= ad["energy_saving_pct"])
    out["prediction_only_exceeds_budget"] = bool(po_exceeds)
    out["hybrid_within_budget"] = bool(hy_within)
    out["hybrid_beats_adaptive_everywhere"] = bool(hy_beats)
    emit("bench/table3/aggregates", 0.0,
         f"po_exceeds_budget={po_exceeds};hybrid_within={hy_within};"
         f"hybrid_beats_adaptive={hy_beats}")
    save_json("table3_predictive", out)
    return out


def run(full: bool = False) -> dict:
    out = {}

    # simulator throughput (rank-task events / s)
    wl, _, _ = baseline_trace("nas_is.D.128")
    us, _ = time_call(lambda: simulate(wl, COUNTDOWN_SLACK)[0], repeats=2)
    events = wl.n_tasks * wl.n_ranks
    out["sim_events_per_s"] = events / (us / 1e6)
    emit("bench/simulator", us, f"events_per_s={out['sim_events_per_s']:.0f}")

    # governor sink throughput (the streaming hot path)
    out["sink_throughput"] = sink_throughput()
    out["governor_events_per_s"] = out["sink_throughput"]["events_per_s"]

    # obs-stack cost on the same stream (acceptance: within 10% of bare)
    out["telemetry_overhead"] = telemetry_overhead()

    # artificial-barrier cost inside the simulator (paper: negligible)
    base, _ = simulate(wl, BASELINE)
    res, _ = simulate(wl, ALL_POLICIES["cntd_slack"])
    out["barrier_overhead_pct"] = res.overhead_vs(base)
    emit("bench/barrier_overhead", 0.0, out["barrier_overhead_pct"])

    # theta sweep: adaptive vs fixed across the workload families
    out["theta_sweep"] = theta_sweep()

    if full:
        import jax.numpy as jnp

        from repro.kernels import ops

        x = jnp.ones((64, 256), jnp.float32)
        sc = jnp.ones((256,), jnp.float32)
        ops.rmsnorm(x, sc).block_until_ready()
        us, _ = time_call(lambda: ops.rmsnorm(x, sc).block_until_ready(), repeats=3)
        emit("bench/rmsnorm_interpret", us, "interpret-mode (CPU)")
    return out


def _cli_arg(name: str, default, cast=float):
    if name in sys.argv:
        return cast(sys.argv[sys.argv.index(name) + 1])
    return default


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "sink_throughput":
        print("name,us_per_call,derived")
        res = sink_throughput()
        print(f"sink_throughput: {res['events_per_s']:,.0f} events/s batched "
              f"({res['speedup']:.1f}x the per-event "
              f"{res['per_event_events_per_s']:,.0f}), "
              f"finalize {res['finalize_s'] * 1e3:.2f} ms, "
              f"{res['n_retained']} records retained, "
              f"reports_equal={res['reports_equal']}")
        if "--check" in sys.argv:
            fails = []
            if res["events_per_s"] < 5e6:
                fails.append(f"batched {res['events_per_s']:,.0f} ev/s "
                             f"< 5M floor")
            if res["speedup"] < 8.0:
                fails.append(f"speedup {res['speedup']:.2f}x < 8x floor")
            if not res["reports_equal"]:
                fails.append("batched GovernorReport != per-event report")
            if fails:
                print("FAIL: " + "; ".join(fails))
                sys.exit(1)
    elif len(sys.argv) > 1 and sys.argv[1] == "telemetry_overhead":
        print("name,us_per_call,derived")
        res = telemetry_overhead()
        print(f"telemetry_overhead: {res['telemetry_events_per_s']:,.0f} "
              f"events/s with full obs stack vs {res['bare_events_per_s']:,.0f} "
              f"bare ({res['overhead_pct']:.1f}% overhead); batched "
              f"{res['batched_telemetry_events_per_s']:,.0f} vs "
              f"{res['batched_bare_events_per_s']:,.0f} "
              f"({res['batched_overhead_pct']:.1f}% overhead)")
        if "--check" in sys.argv:
            fails = []
            if res["ratio"] < 0.9:
                fails.append(f"per-event ratio {res['ratio']:.3f} < 0.9")
            if res["batched_ratio"] < 0.9:
                fails.append(f"batched ratio {res['batched_ratio']:.3f} < 0.9")
            if fails:
                print("FAIL: telemetry overhead exceeds the 10% budget "
                      "(" + "; ".join(fails) + ")")
                sys.exit(1)
    elif len(sys.argv) > 1 and sys.argv[1] == "table3":
        print("name,us_per_call,derived")
        res = table3(
            seed=_cli_arg("--seed", 0, int),
            n_tasks=_cli_arg("--tasks", TABLE3_N_TASKS, int),
        )
        for fam, row in res["families"].items():
            for pol, cell in row.items():
                print(f"table3 {fam:14s} {pol:18s} "
                      f"esave={cell['energy_saving_pct']:6.2f}% "
                      f"ovh={cell['overhead_pct']:6.3f}% "
                      f"dvfs={cell['dvfs_cost_pct']:6.3f}% "
                      f"prearm={cell['n_prearm']} mis={cell['n_mispredict']} "
                      f"trips={cell['n_guard_trips']}")
        print(f"table3: po_exceeds_budget={res['prediction_only_exceeds_budget']} "
              f"hybrid_within_budget={res['hybrid_within_budget']} "
              f"hybrid_beats_adaptive={res['hybrid_beats_adaptive_everywhere']}")
        if "--check" in sys.argv:
            fails = []
            if not res["prediction_only_exceeds_budget"]:
                fails.append("prediction-only stayed under the 1% budget "
                             "on every family (strawman should blow it)")
            if not res["hybrid_within_budget"]:
                fails.append("hybrid overhead exceeded the 1% budget")
            if not res["hybrid_beats_adaptive_everywhere"]:
                fails.append("hybrid energy saving fell below cntd_adaptive")
            if fails:
                print("FAIL: " + "; ".join(fails))
                sys.exit(1)
    elif len(sys.argv) > 1 and sys.argv[1] == "ingest_soak":
        print("name,us_per_call,derived")
        if "--device-producer" in sys.argv:
            dres = device_producer_smoke()
            print(f"device_producer: {dres['n_events_ingested']}/"
                  f"{dres['n_events_expected']} events across "
                  f"{dres['n_devices']} emulated ranks, "
                  f"calls={dres['n_calls']}, ok={dres['ok']}")
            if "--check" in sys.argv and not dres["ok"]:
                print("FAIL: device producer lost or fell back on events")
                sys.exit(1)
        res = ingest_soak(
            n_events=_cli_arg("--events", 10_000_000, int),
            n_ranks=_cli_arg("--ranks", 64, int),
            rss_budget_mb=_cli_arg("--rss-budget-mb", 256.0, float),
        )
        print(f"ingest_soak: {res['events_per_s']:,.0f} events/s over "
              f"{res['n_events']:,} events x {res['n_ranks']} ranks, "
              f"RSS {res['rss_warm_mb']:.0f} -> {res['rss_final_mb']:.0f} MB "
              f"(growth {res['rss_growth_mb']:.1f} MB / budget "
              f"{res['rss_budget_mb']:.0f} MB), "
              f"{res['n_retained']} records retained")
        if "--check" in sys.argv:
            fails = []
            if not res["rss_ok"]:
                fails.append(f"RSS grew {res['rss_growth_mb']:.1f} MB "
                             f"> {res['rss_budget_mb']:.0f} MB budget")
            if not res["delivered_ok"]:
                fails.append("bus ingest counter != published events")
            if fails:
                print("FAIL: " + "; ".join(fails))
                sys.exit(1)
    else:
        run(full=True)
