"""Micro-benchmarks of the framework's own moving parts: simulator
throughput, governor event ingestion, kernel interpret-mode sanity, the
instrumentation overhead of the artificial barrier (paper §4.2 claim:
negligible), and the theta sweep — adaptive theta (cntd_adaptive) vs the
paper's fixed 500 us across the three co-scheduling workload families
(compute-bound / comm-bound / bursty)."""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import baseline_trace, emit, save_json, time_call
from repro.core.governor import Governor
from repro.core.policies import ALL_POLICIES, BASELINE, CNTD_ADAPTIVE, COUNTDOWN_SLACK
from repro.core.simulator import simulate
from repro.core.workloads import APPS, generate

THETA_GRID = (250e-6, 500e-6, 1e-3, 2e-3)
FAMILIES = ("compute_bound", "comm_bound", "bursty_serve")


def theta_sweep(seed: int = 0, n_tasks: int = 400) -> dict:
    """Adaptive vs fixed theta on the three tenant families (DESIGN.md §8).

    For each family: baseline, fixed-theta cntd_slack across ``THETA_GRID``,
    and ``cntd_adaptive`` (online ThetaTuner).  Reports energy saving and
    time-to-completion overhead vs baseline, plus the two acceptance
    aggregates: adaptive beats (or matches) fixed-500us on >= 1 family, and
    adaptive overhead stays under 1% on every family.
    """
    from repro.cluster.coschedule import MIX_SPECS

    out: dict = {"families": {}}
    beats = False
    max_ovh = 0.0
    for fam in FAMILIES:
        spec = dataclasses.replace(MIX_SPECS[fam], n_tasks=n_tasks)
        wl = generate(spec, seed=seed)
        base, _ = simulate(wl, BASELINE)
        row: dict = {}
        for th in THETA_GRID:
            pol = dataclasses.replace(COUNTDOWN_SLACK, theta=th)
            res, _ = simulate(wl, pol)
            row[f"fixed_{th * 1e6:.0f}us"] = {
                "energy_saving_pct": res.energy_saving_vs(base),
                "overhead_pct": res.overhead_vs(base),
            }
        us, ad = time_call(lambda: simulate(wl, CNTD_ADAPTIVE)[0], repeats=1)
        row["adaptive"] = {
            "energy_saving_pct": ad.energy_saving_vs(base),
            "overhead_pct": ad.overhead_vs(base),
            "theta_eff_final_us": float(np.nanmean(ad.theta_series[-20:]) * 1e6),
        }
        out["families"][fam] = row
        fixed500 = row["fixed_500us"]["energy_saving_pct"]
        adaptive = row["adaptive"]["energy_saving_pct"]
        beats = beats or adaptive >= fixed500
        max_ovh = max(max_ovh, row["adaptive"]["overhead_pct"])
        emit(
            f"bench/theta_sweep/{fam}", us,
            f"esave_fixed500={fixed500:.2f};esave_adaptive={adaptive:.2f};"
            f"ovh_adaptive={row['adaptive']['overhead_pct']:.3f}",
        )
    out["adaptive_beats_fixed500"] = bool(beats)
    out["max_overhead_pct"] = float(max_ovh)
    save_json("theta_sweep", out)
    return out


def run(full: bool = False) -> dict:
    out = {}

    # simulator throughput (rank-task events / s)
    wl, _, _ = baseline_trace("nas_is.D.128")
    us, _ = time_call(lambda: simulate(wl, COUNTDOWN_SLACK)[0], repeats=2)
    events = wl.n_tasks * wl.n_ranks
    out["sim_events_per_s"] = events / (us / 1e6)
    emit("bench/simulator", us, f"events_per_s={out['sim_events_per_s']:.0f}")

    # governor ingestion rate
    gov = Governor()
    n_calls, n_ranks = 2000, 16
    t0 = time.perf_counter()
    for c in range(n_calls):
        for r in range(n_ranks):
            gov.sink(r, "barrier_enter", c, c * 1e-3)
            gov.sink(r, "barrier_exit", c, c * 1e-3 + 5e-4)
            gov.sink(r, "copy_exit", c, c * 1e-3 + 7e-4)
    dt = time.perf_counter() - t0
    rep = gov.finalize()
    out["governor_events_per_s"] = 3 * n_calls * n_ranks / dt
    emit("bench/governor", dt * 1e6, f"events_per_s={out['governor_events_per_s']:.0f}")

    # artificial-barrier cost inside the simulator (paper: negligible)
    base, _ = simulate(wl, BASELINE)
    res, _ = simulate(wl, ALL_POLICIES["cntd_slack"])
    out["barrier_overhead_pct"] = res.overhead_vs(base)
    emit("bench/barrier_overhead", 0.0, out["barrier_overhead_pct"])

    # theta sweep: adaptive vs fixed across the workload families
    out["theta_sweep"] = theta_sweep()

    if full:
        import jax.numpy as jnp

        from repro.kernels import ops

        x = jnp.ones((64, 256), jnp.float32)
        sc = jnp.ones((256,), jnp.float32)
        ops.rmsnorm(x, sc).block_until_ready()
        us, _ = time_call(lambda: ops.rmsnorm(x, sc).block_until_ready(), repeats=3)
        emit("bench/rmsnorm_interpret", us, "interpret-mode (CPU)")
    return out


if __name__ == "__main__":
    run(full=True)
