"""Micro-benchmarks of the framework's own moving parts: simulator
throughput, governor sink throughput (events/sec through the streaming
engine — the number the bounded-RSS refactor is held to), kernel
interpret-mode sanity, the instrumentation overhead of the artificial
barrier (paper §4.2 claim: negligible), and the theta sweep — adaptive
theta (cntd_adaptive) vs the paper's fixed 500 us across the three
co-scheduling workload families (compute-bound / comm-bound / bursty).

``python benchmarks/bench_runtime.py sink_throughput`` runs just the
governor hot-path benchmark; ``... telemetry_overhead [--check]`` runs the
obs-stack overhead guard (``--check`` exits non-zero past the 10% budget).
"""
from __future__ import annotations

import dataclasses
import sys
import time

import numpy as np

from benchmarks.common import baseline_trace, emit, save_json, time_call
from repro.core.governor import Governor
from repro.core.policies import ALL_POLICIES, BASELINE, CNTD_ADAPTIVE, COUNTDOWN_SLACK
from repro.core.simulator import simulate
from repro.core.workloads import APPS, generate

THETA_GRID = (250e-6, 500e-6, 1e-3, 2e-3)
FAMILIES = ("compute_bound", "comm_bound", "bursty_serve")


def sink_throughput(n_calls: int = 4000, n_ranks: int = 16,
                    repeats: int = 5) -> dict:
    """Events/sec through ``Governor.sink`` on a downshift-heavy stream.

    The stream is the runtime's worst case: recurring call ids (every
    occurrence rotates through retirement + streaming accumulation), 1 ms
    slack over the 500 us default theta (every barrier_exit books an
    actuation pair).  Reported: best-of-``repeats`` events/sec, the
    finalize() wall time after the full stream (must stay flat — it is an
    O(in-flight) read of the accumulators), and the retained-record count
    (bounded by the governor's retention ring, not the stream length).
    """
    def stream(gov: Governor) -> float:
        t0 = time.perf_counter()
        t = 0.0
        for c in range(n_calls):
            cid = c % 50                    # call ids recur: rotation path
            for r in range(n_ranks):
                gov.sink(r, "barrier_enter", cid, t + r * 1e-6)
            for r in range(n_ranks):
                gov.sink(r, "barrier_exit", cid, t + 1e-3)
                gov.sink(r, "copy_exit", cid, t + 1.2e-3)
            t += 2e-3
        return 3 * n_calls * n_ranks / (time.perf_counter() - t0)

    best = 0.0
    gov = None
    for _ in range(repeats):
        gov = Governor()
        best = max(best, stream(gov))
    t0 = time.perf_counter()
    rep = gov.finalize()
    t_fin = time.perf_counter() - t0
    out = {
        "events_per_s": best,
        "n_events": 3 * n_calls * n_ranks,
        "finalize_s": t_fin,
        "n_retained": len(gov.recent_records()),
        "n_calls": rep.n_calls,
    }
    emit("bench/sink_throughput", 1e6 / best,
         f"events_per_s={best:.0f};finalize_s={t_fin:.4f};"
         f"retained={out['n_retained']}")
    return out


def telemetry_overhead(n_calls: int = 2500, n_ranks: int = 16,
                       repeats: int = 7) -> dict:
    """The obs-stack overhead guard: ``sink_throughput``'s event stream
    through an :class:`~repro.core.events.EventBus` with (A) only the
    governor subscribed (the bare-bus baseline) vs (B) the full telemetry
    stack attached the way the launch drivers wire it — a
    :class:`~repro.obs.tracer.GovernorTap` in the governor's recorder slot
    forwarding retired occurrences and theta decisions to a
    :class:`~repro.obs.tracer.SpanTracer` and a
    :class:`~repro.obs.metrics.BusMetrics`, plus the cold-path costs the
    report cadence pays (a registry snapshot and the spine-log actuation
    pull).

    A and B are interleaved (A,B,A,B,...) and compared on per-arm medians,
    so ambient load lands on both arms instead of whichever ran second.
    The acceptance bar (CI ``--check``): B within 10% of A
    (``ratio >= 0.9``).
    """
    from repro.core.events import EventBus
    from repro.obs.metrics import BusMetrics, MetricsRegistry
    from repro.obs.tracer import GovernorTap, SpanTracer

    n_events = 3 * n_calls * n_ranks

    def stream(bus: EventBus) -> float:
        t0 = time.perf_counter()
        t = 0.0
        for c in range(n_calls):
            cid = c % 50
            for r in range(n_ranks):
                bus.publish(r, "barrier_enter", cid, t + r * 1e-6)
            for r in range(n_ranks):
                bus.publish(r, "barrier_exit", cid, t + 1e-3)
                bus.publish(r, "copy_exit", cid, t + 1.2e-3)
            t += 2e-3
        return n_events / (time.perf_counter() - t0)

    def bare() -> float:
        bus = EventBus()
        bus.subscribe(Governor())
        return stream(bus)

    def attached() -> float:
        registry = MetricsRegistry()
        tracer = SpanTracer()
        tap = GovernorTap(tracer, metrics=BusMetrics(registry))
        gov = Governor(recorder=tap)
        bus = EventBus()
        bus.subscribe(gov)
        rate = stream(bus)
        registry.snapshot()             # include the collector-sync cost
        tracer.ingest_governor(gov)     # ... and the export-time spine pull
        return rate

    rates_a, rates_b = [], []
    for _ in range(repeats):
        rates_a.append(bare())
        rates_b.append(attached())
    med_a = float(np.median(rates_a))
    med_b = float(np.median(rates_b))
    out = {
        "bare_events_per_s": med_a,
        "telemetry_events_per_s": med_b,
        "ratio": med_b / med_a,
        "overhead_pct": 100.0 * (1.0 - med_b / med_a),
        "n_events": n_events,
        "repeats": repeats,
    }
    emit("bench/telemetry_overhead", 1e6 / med_b,
         f"bare={med_a:.0f};telemetry={med_b:.0f};ratio={out['ratio']:.3f}")
    return out


def theta_sweep(seed: int = 0, n_tasks: int = 400) -> dict:
    """Adaptive vs fixed theta on the three tenant families (DESIGN.md §8).

    For each family: baseline, fixed-theta cntd_slack across ``THETA_GRID``,
    and ``cntd_adaptive`` (online ThetaTuner).  Reports energy saving and
    time-to-completion overhead vs baseline, plus the two acceptance
    aggregates: adaptive beats (or matches) fixed-500us on >= 1 family, and
    adaptive overhead stays under 1% on every family.
    """
    from repro.cluster.coschedule import MIX_SPECS

    out: dict = {"families": {}}
    beats = False
    max_ovh = 0.0
    for fam in FAMILIES:
        spec = dataclasses.replace(MIX_SPECS[fam], n_tasks=n_tasks)
        wl = generate(spec, seed=seed)
        base, _ = simulate(wl, BASELINE)
        row: dict = {}
        for th in THETA_GRID:
            pol = dataclasses.replace(COUNTDOWN_SLACK, theta=th)
            res, _ = simulate(wl, pol)
            row[f"fixed_{th * 1e6:.0f}us"] = {
                "energy_saving_pct": res.energy_saving_vs(base),
                "overhead_pct": res.overhead_vs(base),
            }
        us, ad = time_call(lambda: simulate(wl, CNTD_ADAPTIVE)[0], repeats=1)
        row["adaptive"] = {
            "energy_saving_pct": ad.energy_saving_vs(base),
            "overhead_pct": ad.overhead_vs(base),
            "theta_eff_final_us": float(np.nanmean(ad.theta_series[-20:]) * 1e6),
        }
        out["families"][fam] = row
        fixed500 = row["fixed_500us"]["energy_saving_pct"]
        adaptive = row["adaptive"]["energy_saving_pct"]
        beats = beats or adaptive >= fixed500
        max_ovh = max(max_ovh, row["adaptive"]["overhead_pct"])
        emit(
            f"bench/theta_sweep/{fam}", us,
            f"esave_fixed500={fixed500:.2f};esave_adaptive={adaptive:.2f};"
            f"ovh_adaptive={row['adaptive']['overhead_pct']:.3f}",
        )
    out["adaptive_beats_fixed500"] = bool(beats)
    out["max_overhead_pct"] = float(max_ovh)
    save_json("theta_sweep", out)
    return out


def run(full: bool = False) -> dict:
    out = {}

    # simulator throughput (rank-task events / s)
    wl, _, _ = baseline_trace("nas_is.D.128")
    us, _ = time_call(lambda: simulate(wl, COUNTDOWN_SLACK)[0], repeats=2)
    events = wl.n_tasks * wl.n_ranks
    out["sim_events_per_s"] = events / (us / 1e6)
    emit("bench/simulator", us, f"events_per_s={out['sim_events_per_s']:.0f}")

    # governor sink throughput (the streaming hot path)
    out["sink_throughput"] = sink_throughput()
    out["governor_events_per_s"] = out["sink_throughput"]["events_per_s"]

    # obs-stack cost on the same stream (acceptance: within 10% of bare)
    out["telemetry_overhead"] = telemetry_overhead()

    # artificial-barrier cost inside the simulator (paper: negligible)
    base, _ = simulate(wl, BASELINE)
    res, _ = simulate(wl, ALL_POLICIES["cntd_slack"])
    out["barrier_overhead_pct"] = res.overhead_vs(base)
    emit("bench/barrier_overhead", 0.0, out["barrier_overhead_pct"])

    # theta sweep: adaptive vs fixed across the workload families
    out["theta_sweep"] = theta_sweep()

    if full:
        import jax.numpy as jnp

        from repro.kernels import ops

        x = jnp.ones((64, 256), jnp.float32)
        sc = jnp.ones((256,), jnp.float32)
        ops.rmsnorm(x, sc).block_until_ready()
        us, _ = time_call(lambda: ops.rmsnorm(x, sc).block_until_ready(), repeats=3)
        emit("bench/rmsnorm_interpret", us, "interpret-mode (CPU)")
    return out


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "sink_throughput":
        print("name,us_per_call,derived")
        res = sink_throughput()
        print(f"sink_throughput: {res['events_per_s']:,.0f} events/s, "
              f"finalize {res['finalize_s'] * 1e3:.2f} ms, "
              f"{res['n_retained']} records retained")
    elif len(sys.argv) > 1 and sys.argv[1] == "telemetry_overhead":
        print("name,us_per_call,derived")
        res = telemetry_overhead()
        print(f"telemetry_overhead: {res['telemetry_events_per_s']:,.0f} "
              f"events/s with full obs stack vs {res['bare_events_per_s']:,.0f} "
              f"bare ({res['overhead_pct']:.1f}% overhead)")
        if "--check" in sys.argv and res["ratio"] < 0.9:
            print(f"FAIL: telemetry overhead {res['overhead_pct']:.1f}% "
                  f"exceeds the 10% budget (ratio {res['ratio']:.3f} < 0.9)")
            sys.exit(1)
    else:
        run(full=True)
