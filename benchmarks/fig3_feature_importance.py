"""Paper Fig. 3: permutation feature importance of the RF duration models
(averaged over applications, normalized to [0,1] per target)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import baseline_trace, emit, save_json, time_call
from repro.core.predictor import FEATURES_BASE, FEATURES_PREV, evaluate_predictability

APPS_FIG3 = ["nas_is.D.128", "nas_mg.E.128", "nas_ft.E.1024", "omen_1056p"]


def run(full: bool = False) -> dict:
    feats = FEATURES_BASE + FEATURES_PREV
    acc = {t: {f: [] for f in feats} for t in ("tcomp", "tslack", "tcopy")}
    for app in APPS_FIG3:
        _, _, trace = baseline_trace(app)
        us, res = time_call(
            lambda: evaluate_predictability(app, trace, with_prev=True,
                                            n_trees=5, importance=True),
            repeats=1,
        )
        for tgt, imps in res.importance.items():
            for f, v in imps.items():
                acc[tgt][f].append(v)
        emit(f"fig3/{app}", us, "ok")
    fig = {
        tgt: {
            f: {"mean": float(np.mean(v)), "std": float(np.std(v))}
            for f, v in by_feat.items() if v
        }
        for tgt, by_feat in acc.items()
    }
    for tgt in fig:
        top = sorted(fig[tgt], key=lambda f: -fig[tgt][f]["mean"])[:3]
        emit(f"fig3/top_features/{tgt}", 0.0, ";".join(top))
    save_json("fig3_feature_importance", fig)
    return fig


if __name__ == "__main__":
    run(full=True)
