"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell:
  compute term    = HLO_FLOPs_per_chip / peak_FLOPs_per_chip
  memory term     = HLO_bytes_per_chip / HBM_bandwidth
  collective term = wire_bytes_per_chip / ICI_link_bandwidth
(cost_analysis numbers come from the per-device SPMD module, so the
"per chip" division is already done; see launch/dryrun.py.)

Also reports MODEL_FLOPS = 6*N*D (N_active for MoE) and the useful-compute
ratio MODEL_FLOPS / (HLO_FLOPs * chips), catching remat/redundancy waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, Optional

from benchmarks.common import ART, emit, save_json

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # B/s
ICI_BW = 50e9              # B/s per link

DRYRUN_DIR = os.path.join(ART, "dryrun")


def _model_flops(arch: str, shape_name: str) -> float:
    from repro.configs import SHAPES, get_config

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_total = cfg.param_count(active_only=cfg.is_moe)
    embed = cfg.vocab * cfg.d_model
    n = max(n_total - embed, 1)                    # non-embedding params
    if shape.kind == "decode":
        tokens = shape.global_batch                # one new token per sequence
    else:
        tokens = shape.global_batch * shape.seq_len
    mult = 6.0 if shape.kind == "train" else 2.0   # fwd+bwd vs fwd
    return mult * n * tokens


def _advice(dominant: str, shape_kind: str) -> str:
    if dominant == "collective":
        return ("overlap the collective with compute (async reduce, "
                "collective-matmul) or re-shard to cut wire bytes")
    if dominant == "memory":
        if shape_kind == "decode":
            return ("decode is KV-cache-bandwidth-bound: shrink the cache "
                    "(window/quantize/GQA-pack) or batch more sequences per pass")
        return "fuse ops / cut remat recompute to reduce HBM round-trips"
    return "raise MXU utilization (larger tiles, fewer transposes, bf16 paths)"


def analyze_cell(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    if "cost" not in rec:
        return None                                # scan-only multipod cell
    chips = rec["n_devices"]
    flops_dev = rec["cost"]["flops"]
    # prefer the top-level-tensor HBM proxy (cost_analysis counts
    # fusion-internal bytes + CPU-only converts; see launch/dryrun.py)
    bytes_dev = rec.get("traffic", {}).get("traffic_bytes", rec["cost"]["bytes_accessed"])
    wire_dev = sum(op["wire_bytes"] for op in rec["collectives"].values())
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_collective = wire_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_collective}
    dominant = max(terms, key=terms.get)
    model_fl = _model_flops(rec["arch"], rec["shape"])
    useful_ratio = model_fl / max(flops_dev * chips, 1.0)
    ideal = model_fl / chips / PEAK_FLOPS
    bound = max(terms.values())
    from repro.configs import SHAPES

    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "tag": rec.get("tag", ""),
        "chips": chips,
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_collective,
        "dominant": dominant,
        "model_flops": model_fl,
        "useful_flops_ratio": useful_ratio,
        "roofline_fraction": ideal / bound if bound > 0 else 0.0,
        "peak_mem_gib": rec["memory"]["peak_args_plus_temp"] / 2**30,
        "advice": _advice(dominant, SHAPES[rec["shape"]].kind),
    }


def run(full: bool = True) -> dict:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        row = analyze_cell(rec)
        if row is None:
            continue
        rows.append(row)
        emit(
            f"roofline/{row['arch']}/{row['shape']}/{row['mesh']}"
            + (f"/{row['tag']}" if row["tag"] else ""),
            0.0,
            f"dom={row['dominant']};frac={row['roofline_fraction']:.4f};"
            f"c={row['compute_s']:.2e};m={row['memory_s']:.2e};x={row['collective_s']:.2e}",
        )
    save_json("roofline", rows)
    return {"cells": rows}


if __name__ == "__main__":
    run()
