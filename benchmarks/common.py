"""Shared benchmark utilities: timing, CSV output, workload/trace caching."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
os.makedirs(ART, exist_ok=True)

_META_CACHE: Optional[Dict[str, Any]] = None


def run_meta() -> Dict[str, Any]:
    """Provenance stamp for benchmark artifacts: which code produced this
    number, when, with what invocation.  Cached per process (the git
    lookup is a subprocess)."""
    global _META_CACHE
    if _META_CACHE is None:
        try:
            sha = subprocess.run(
                ["git", "rev-parse", "HEAD"],
                cwd=os.path.dirname(os.path.abspath(__file__)),
                capture_output=True, text=True, timeout=5,
            ).stdout.strip() or None
        except (OSError, subprocess.SubprocessError):
            sha = None
        _META_CACHE = {
            "git_sha": sha,
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
            "argv": list(sys.argv),
            "python": sys.version.split()[0],
        }
    return dict(_META_CACHE)

_TRACE_CACHE: Dict[Tuple[str, int], Any] = {}


def baseline_trace(app: str, seed: int = 0):
    """(workload, baseline SimResult, TraceRecord) — cached per process."""
    from repro.core.policies import BASELINE
    from repro.core.simulator import simulate
    from repro.core.workloads import APPS, generate

    key = (app, seed)
    if key not in _TRACE_CACHE:
        wl = generate(APPS[app], seed=seed)
        res, trace = simulate(wl, BASELINE, collect_trace=True)
        _TRACE_CACHE[key] = (wl, res, trace)
    return _TRACE_CACHE[key]


def time_call(fn: Callable[[], Any], repeats: int = 3) -> Tuple[float, Any]:
    """(best microseconds per call, last result)."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return best, out


def emit(name: str, us_per_call: float, derived: Any) -> None:
    """The harness contract: ``name,us_per_call,derived`` CSV on stdout."""
    if isinstance(derived, float):
        derived = f"{derived:.4f}"
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def save_json(name: str, payload: Any) -> str:
    """Write one artifact; dict payloads are stamped with ``_meta``
    provenance (git sha, timestamp, argv) without mutating the caller's
    object."""
    if isinstance(payload, dict) and "_meta" not in payload:
        payload = {**payload, "_meta": run_meta()}
    path = os.path.join(ART, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path
