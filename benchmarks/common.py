"""Shared benchmark utilities: timing, CSV output, workload/trace caching."""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
os.makedirs(ART, exist_ok=True)

_TRACE_CACHE: Dict[Tuple[str, int], Any] = {}


def baseline_trace(app: str, seed: int = 0):
    """(workload, baseline SimResult, TraceRecord) — cached per process."""
    from repro.core.policies import BASELINE
    from repro.core.simulator import simulate
    from repro.core.workloads import APPS, generate

    key = (app, seed)
    if key not in _TRACE_CACHE:
        wl = generate(APPS[app], seed=seed)
        res, trace = simulate(wl, BASELINE, collect_trace=True)
        _TRACE_CACHE[key] = (wl, res, trace)
    return _TRACE_CACHE[key]


def time_call(fn: Callable[[], Any], repeats: int = 3) -> Tuple[float, Any]:
    """(best microseconds per call, last result)."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return best, out


def emit(name: str, us_per_call: float, derived: Any) -> None:
    """The harness contract: ``name,us_per_call,derived`` CSV on stdout."""
    if isinstance(derived, float):
        derived = f"{derived:.4f}"
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def save_json(name: str, payload: Any) -> str:
    path = os.path.join(ART, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path
