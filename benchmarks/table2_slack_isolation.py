"""Paper Table 2: slack-isolation potential — coverage [% of execution time]
each policy can run at the minimum P-state, on the baseline trace."""
from __future__ import annotations

from benchmarks.common import baseline_trace, emit, save_json, time_call
from repro.core.policies import ALL_POLICIES
from repro.core.simulator import coverage_on_trace
from repro.core.workloads import APPS

POLICIES = ["fermata_100ms", "fermata_500us", "countdown", "cntd_slack"]

# Paper Table 2 reference [%]: Tcomm, Tslack, F100, F500, CNTD, CNTDS
PAPER = {
    "nas_bt.E.1024": (0.12, 0.07, 0.00, 0.00, 0.12, 0.07),
    "nas_cg.E.1024": (34.84, 0.07, 0.39, 32.68, 32.96, 0.01),
    "nas_ep.E.128": (7.56, 7.56, 0.00, 0.00, 7.56, 7.56),
    "nas_ft.E.1024": (65.10, 12.28, 55.88, 57.80, 65.09, 12.28),
    "nas_is.D.128": (62.73, 27.42, 31.14, 40.98, 62.65, 27.41),
    "nas_lu.E.1024": (51.01, 45.51, 9.91, 21.93, 22.42, 21.79),
    "nas_mg.E.128": (8.94, 0.09, 0.01, 7.95, 8.48, 0.06),
    "nas_sp.E.1024": (0.05, 0.02, 0.00, 0.00, 0.05, 0.02),
    "omen_60p": (59.69, 56.00, 43.87, 48.86, 59.60, 55.99),
    "omen_1056p": (62.96, 56.42, 50.85, 60.18, 62.83, 56.41),
}


def run(full: bool = True) -> dict:
    table = {}
    for app in APPS:
        wl, base, trace = baseline_trace(app)
        total = base.tcomp + base.tslack + base.tcopy
        row = {
            "tcomm_pct": 100 * (base.tslack + base.tcopy) / total,
            "tslack_pct": 100 * base.tslack / total,
            "avg_mpi_ms": 1000 * (base.tslack + base.tcopy) / (base.calls * wl.n_ranks),
        }
        for pol in POLICIES:
            us, cov = time_call(
                lambda p=pol: coverage_on_trace(trace, ALL_POLICIES[p]), repeats=1
            )
            row[pol] = cov
            emit(f"table2/{app}/{pol}", us, cov)
        row["paper"] = dict(
            zip(("tcomm", "tslack", "f100", "f500", "cntd", "cntds"), PAPER[app])
        )
        table[app] = row
    save_json("table2_slack_isolation", table)
    return table


if __name__ == "__main__":
    run()
