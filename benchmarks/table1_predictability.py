"""Paper Table 1: region-duration predictability (SMAPE, random forests),
with and without previous-call information."""
from __future__ import annotations

from benchmarks.common import baseline_trace, emit, save_json, time_call
from repro.core.predictor import evaluate_predictability
from repro.core.workloads import APPS

# Paper Table 1 reference values (SMAPE %): (tcomp, tslack, tcopy)
PAPER = {
    "nas_bt.E.1024": ((57.0, 17.6, 52.5), (6.2, 12.4, 12.4)),
    "nas_cg.E.1024": ((21.9, 7.1, 25.3), (16.2, 5.5, 11.0)),
    "nas_ep.E.128": ((9.1, 8.4, 23.8), (9.7, 7.3, 24.6)),
    "nas_ft.E.1024": ((1.2, 5.4, 9.7), (0.3, 1.2, 3.9)),
    "nas_is.D.128": ((10.7, 15.2, 8.2), (5.3, 8.0, 2.4)),
    "nas_lu.E.1024": ((0.9, 19.8, 0.5), (0.7, 13.5, 0.4)),
    "nas_mg.E.128": ((5.1, 4.8, 13.0), (4.1, 5.3, 13.1)),
    "nas_sp.E.1024": ((46.5, 11.8, 46.9), (4.1, 10.2, 7.3)),
    "omen_1056p": ((1.0, 57.3, 75.8), (2.8, 55.4, 64.6)),
}

FAST_APPS = [
    "nas_cg.E.1024", "nas_ft.E.1024", "nas_is.D.128", "nas_mg.E.128",
    "omen_1056p",
]


def run(full: bool = False) -> dict:
    apps = list(APPS) if full else FAST_APPS
    table = {}
    for app in apps:
        _, _, trace = baseline_trace(app)
        row = {}
        for prev in (False, True):
            us, res = time_call(
                lambda: evaluate_predictability(app, trace, prev, n_trees=6),
                repeats=1,
            )
            key = "with_prev" if prev else "no_prev"
            row[key] = res.smape
            emit(
                f"table1/{app}/{key}",
                us,
                "tcomp={tcomp:.1f};tslack={tslack:.1f};tcopy={tcopy:.1f}".format(**res.smape),
            )
        if app in PAPER:
            row["paper_no_prev"] = dict(zip(("tcomp", "tslack", "tcopy"), PAPER[app][0]))
            row["paper_with_prev"] = dict(zip(("tcomp", "tslack", "tcopy"), PAPER[app][1]))
        table[app] = row
    save_json("table1_predictability", table)
    return table


if __name__ == "__main__":
    run(full=True)
