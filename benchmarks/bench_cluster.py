"""Cluster benchmark: slack-driven cap arbitration vs static equal-split.

A heterogeneous two-job mix — one compute-bound (EP-like, every watt is
progress) and one bursty-serve (decode-shaped, watts above the floor are
stranded in slack) — runs twice under the same fixed cluster cap:

* **static** — cap / n_jobs forever, the facility default;
* **arbiter** — :class:`PowerBudgetArbiter` re-splits each epoch on the
  jobs' exploited-slack ratios (AIMD, per-job floor).

The cap is sized *tight* (below the mix's aggregate f_max demand): that is
the regime the arbiter exists for — equal split strands watts in the
slack-rich job while pinning the critical job below the energy-optimal
frequency, so redistribution wins on both axes.  The acceptance bar
mirrors the paper's performance-neutrality: lower total energy at <= 1 %
makespan overhead.

Also times the trace layer: record a synthetic governor stream, replay it
through a fresh governor, and assert the slack/energy totals reproduce
bit-for-bit (the record/replay contract the offline what-if loop rests
on).

Emits the standard ``name,us_per_call,derived`` CSV contract plus a JSON
artifact.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json, time_call

CAP_W = 100.0
FLOOR_W = 15.0


def _mix(floor_w: float = FLOOR_W):
    from repro.cluster import make_job

    return [
        make_job("compute_bound", seed=1, floor_w=floor_w),
        make_job("bursty_serve", seed=2, floor_w=floor_w),
    ]


def _trace_roundtrip(n_calls: int, n_ranks: int = 8):
    from repro.core.governor import Governor
    from repro.cluster.trace import TraceRecorder, replay

    rec = TraceRecorder()
    gov = Governor(recorder=rec)
    rng = np.random.default_rng(0)
    t = 1.0
    for call in range(n_calls):
        arrivals = t + rng.uniform(0.0, 3e-3, n_ranks)
        release = float(arrivals.max())
        for r in range(n_ranks):
            gov.sink(r, "barrier_enter", call, float(arrivals[r]))
        for r in range(n_ranks):
            gov.sink(r, "barrier_exit", call, release)
            gov.sink(r, "copy_exit", call, release + 0.5e-3)
        t = release + 5e-3
    live = gov.finalize()
    records = rec.records()

    def run_replay():
        _, rep = replay(records)
        return rep

    us, rep = time_call(run_replay)
    exact = (
        rep.total_slack == live.total_slack
        and rep.total_copy == live.total_copy
        and rep.energy_baseline == live.energy_baseline
        and rep.energy_policy == live.energy_policy
        and rep.n_calls == live.n_calls
    )
    return us, len(records), exact


def run(full: bool = False) -> dict:
    from repro.cluster import PowerBudgetArbiter, StaticEqualSplit, run_coschedule

    static = run_coschedule(
        _mix(), CAP_W, arbiter=StaticEqualSplit(cap_w=CAP_W, floor_w=FLOOR_W)
    )
    arbited = run_coschedule(
        _mix(), CAP_W, arbiter=PowerBudgetArbiter(cap_w=CAP_W, floor_w=FLOOR_W)
    )

    overhead_pct = 100.0 * (arbited.makespan_s / static.makespan_s - 1.0)
    saving_pct = 100.0 * (1.0 - arbited.energy_j / static.energy_j)
    wins = saving_pct > 0.0 and overhead_pct <= 1.0

    emit("cluster.static_split", static.makespan_s * 1e6 / max(static.energy_j, 1),
         f"makespan={static.makespan_s:.2f}s;energy={static.energy_j:.0f}J")
    emit("cluster.arbiter", arbited.makespan_s * 1e6 / max(arbited.energy_j, 1),
         f"makespan={arbited.makespan_s:.2f}s;energy={arbited.energy_j:.0f}J")
    emit("cluster.arbiter_vs_static", abs(overhead_pct),
         f"energy_saving={saving_pct:.2f}%;overhead={overhead_pct:.2f}%;wins={wins}")

    n_calls = 2000 if full else 400
    us, n_records, exact = _trace_roundtrip(n_calls)
    emit("cluster.trace_replay", us / max(n_records, 1),
         f"records={n_records};bitwise_exact={exact}")

    payload = {
        "cap_w": CAP_W,
        "floor_w": FLOOR_W,
        "static": static.summary(),
        "arbiter": arbited.summary(),
        "arbiter_allocations": arbited.allocations,
        "energy_saving_pct": saving_pct,
        "makespan_overhead_pct": overhead_pct,
        "arbiter_wins": wins,
        "trace_replay": {"n_records": n_records, "us_per_record": us / max(n_records, 1),
                         "bitwise_exact": exact},
    }
    save_json("bench_cluster", payload)
    return payload
