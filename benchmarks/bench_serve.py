"""Serving benchmark: static vs continuous vs continuous+pallas batching.

Replays the same Poisson-with-bursts arrival trace (heterogeneous
``max_new`` per request) through three engines:

* **static** — the legacy :class:`ServeEngine` batching discipline:
  assemble ``n_slots`` requests in arrival order (idling until the whole
  group has arrived), decode every slot for the group's *longest*
  request, repeat.  Finished/padded slots burn full-width decode steps —
  the serving analogue of spinning at f_max inside a blocking call.
* **continuous** — :class:`ContinuousEngine` over the paged KV pool:
  join-on-prefill / evict-on-EOS keeps the batch full, idle gaps and
  per-step underfill are reported to a :class:`Governor` which prices
  the slack in joules and books ``set_pstate_min`` actuation pairs.
* **continuous+pallas** — the same engine with ``attn_kernel="pallas"``:
  the paged-decode attention kernel with the fused dequant/scatter/sample
  epilogue.  The bursty trace is arrival-bound, so both paged arms are
  *also* timed steady-state (full batch, timed decode steps through the
  real session loop) — ``decode_tok_s`` is the decode-bound number the
  kernel actually moves.

``--check`` asserts the pallas arm's sampled tokens are bit-identical to
the XLA arm's per request, and that saturated continuous+pallas tok/s is
at least continuous tok/s (on this CPU host the kernel runs in interpret
mode; compiled backends carry the headline).

Emits the standard ``name,us_per_call,derived`` CSV contract plus a JSON
artifact with tok/s, fill fraction, priced slack energy and actuations.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json


def _trace(cfg, n: int, prompt_len: int, seed: int = 0):
    from repro.serve import Request, poisson_arrivals

    rng = np.random.default_rng(seed)
    arrivals = poisson_arrivals(n, rate=40.0, seed=seed, burst_every=4,
                                burst_gap=0.08)
    reqs = []
    for i in range(n):
        prompt = rng.integers(0, cfg.vocab, size=prompt_len).astype(np.int32)
        max_new = int(rng.integers(3, 17))
        reqs.append(Request(prompt=prompt, max_new=max_new,
                            arrival=float(arrivals[i])))
    return reqs


def _run_static(eng, reqs, n_slots: int, t_start: float) -> int:
    """Static discipline: fixed groups in arrival order, longest member
    sets the group's step count, the group waits for its last arrival."""
    import jax
    import jax.numpy as jnp

    n_tok = 0
    for i in range(0, len(reqs), n_slots):
        group = reqs[i:i + n_slots]
        wait = t_start + max(r.arrival for r in group) - time.monotonic()
        if wait > 0:
            time.sleep(wait)
        batch = {"tokens": jnp.asarray(np.stack([r.prompt for r in group]))}
        steps = max(r.max_new for r in group)
        out = jax.block_until_ready(eng.generate(batch, n_steps=steps))
        n_tok += sum(min(r.max_new, out.shape[1]) for r in group)
    return n_tok


def _steady_decode_round(eng, prompt_len: int, steps: int = 24) -> np.ndarray:
    """One steady-state decode round at a full batch: join ``n_slots``
    requests, then time ``steps`` batched decode steps through the real
    session loop (host sampling, table clamping and all).  The bursty
    trace is arrival-bound and join/prefill cost is kernel-independent,
    so this is the path the decode kernel actually moves.  Returns the
    per-step wall seconds; callers interleave rounds across the engines
    under comparison and keep each step's elementwise minimum — a host
    scheduler noise burst then only costs the steps it actually landed
    on, in whichever arm, instead of deciding the whole comparison."""
    from repro.serve import Request
    from repro.serve.engine import EngineSession

    rng = np.random.default_rng(1)
    sess = EngineSession(eng)
    for _ in range(eng.n_slots):
        prompt = rng.integers(0, eng.cfg.vocab, size=prompt_len).astype(np.int32)
        sess.submit(Request(prompt=prompt, max_new=steps + 4, arrival=0.0))
    sess.admit(now=0.0)
    for _ in range(3):                        # touch every width bucket
        sess.decode_step()
    dts = np.empty(steps)
    for i in range(steps):
        t0 = time.monotonic()
        sess.decode_step()
        dts[i] = time.monotonic() - t0
    while not sess.done:                      # drain so pages free up
        sess.decode_step()
    return dts


def run(full: bool = False, check: bool = False) -> dict:
    import jax

    from repro.configs import get_config, reduced
    from repro.core.governor import Governor
    from repro.models import init_params
    from repro.serve import ContinuousEngine, ServeEngine, SLOTracker

    n_requests = 16 if full else 10
    n_slots, prompt_len, page = 4, 16, 8
    cfg = reduced(get_config("llama3.2-1b"))
    params = init_params(cfg, jax.random.PRNGKey(0))

    static_eng = ServeEngine(cfg, params, max_len=48)
    cont_eng = ContinuousEngine(cfg, params, n_slots=n_slots, max_len=48, page=page)
    pall_eng = ContinuousEngine(cfg, params, n_slots=n_slots, max_len=48, page=page,
                                attn_kernel="pallas")

    # warmup all engines so tok/s excludes compile
    warm = {"tokens": np.zeros((n_slots, prompt_len), np.int32)}
    jax.block_until_ready(static_eng.generate(warm, n_steps=16))
    cont_eng.generate({"tokens": warm["tokens"][:1]}, n_steps=16)
    pall_eng.generate({"tokens": warm["tokens"][:1]}, n_steps=16)

    reqs_s = _trace(cfg, n_requests, prompt_len)
    t0 = time.monotonic()
    tok_s = _run_static(static_eng, reqs_s, n_slots, t0)
    dt_s = time.monotonic() - t0
    static_tok_s = tok_s / dt_s

    gov = Governor()
    slo = SLOTracker()
    reqs_c = _trace(cfg, n_requests, prompt_len)
    t0 = time.monotonic()
    done = cont_eng.serve(reqs_c, governor=gov, slo=slo)
    dt_c = time.monotonic() - t0
    tok_c = sum(len(r.out) for r in done)
    cont_tok_s = tok_c / dt_c
    meter = cont_eng._last_meter

    # pallas arm: same bursty trace for the wall-clock column...
    reqs_p = _trace(cfg, n_requests, prompt_len)
    t0 = time.monotonic()
    done_p = pall_eng.serve(reqs_p)
    dt_p = time.monotonic() - t0
    tok_p = sum(len(r.out) for r in done_p)
    pallas_tok_s = tok_p / dt_p
    # ...and a steady-state full-batch loop for the decode-bound
    # comparison (the bursty trace is arrival-dominated, which would
    # mask the kernel).  Rounds interleave the two arms and each arm
    # keeps its per-step elementwise-minimum latency profile.
    cont_dts = pall_dts = None
    _steady_decode_round(cont_eng, prompt_len)    # warm width buckets
    _steady_decode_round(pall_eng, prompt_len)
    for _ in range(5):
        c = _steady_decode_round(cont_eng, prompt_len)
        p = _steady_decode_round(pall_eng, prompt_len)
        cont_dts = c if cont_dts is None else np.minimum(cont_dts, c)
        pall_dts = p if pall_dts is None else np.minimum(pall_dts, p)
    cont_dec_tok_s = n_slots * len(cont_dts) / cont_dts.sum()
    pall_dec_tok_s = n_slots * len(pall_dts) / pall_dts.sum()

    # attention archs decode each request independently of batch
    # composition, so per-request outputs must be bit-identical
    tokens_equal = all(
        rc.out == rp.out for rc, rp in zip(reqs_c, reqs_p)
    )

    rep = gov.finalize()
    slack_j = rep.energy_baseline - rep.energy_policy
    pairs = sum(1 for a in gov.actuation_log if a.action == "set_pstate_min")

    emit("serve.static_tok_s", dt_s * 1e6 / max(tok_s, 1), f"{static_tok_s:.1f}tok_s")
    emit("serve.continuous_tok_s", dt_c * 1e6 / max(tok_c, 1),
         f"{cont_tok_s:.1f}tok_s;speedup={cont_tok_s / max(static_tok_s, 1e-9):.2f}x")
    emit("serve.pallas_tok_s", dt_p * 1e6 / max(tok_p, 1),
         f"{pallas_tok_s:.1f}tok_s"
         f";decode_speedup={pall_dec_tok_s / max(cont_dec_tok_s, 1e-9):.2f}x"
         f";tokens_equal={tokens_equal}")
    emit("serve.decode_slack", rep.total_slack * 1e6,
         f"slack_J={slack_j:.3f};downshift_pairs={pairs};fill={meter.fill_fraction:.2f}")

    payload = {
        "n_requests": n_requests,
        "static": {"tok_s": static_tok_s, "tokens": tok_s, "elapsed_s": dt_s},
        "continuous": {
            "tok_s": cont_tok_s, "tokens": tok_c, "elapsed_s": dt_c,
            "fill_fraction": meter.fill_fraction,
            "speedup": cont_tok_s / max(static_tok_s, 1e-9),
            "decode_tok_s": cont_dec_tok_s,
        },
        "pallas": {
            "tok_s": pallas_tok_s, "tokens": tok_p, "elapsed_s": dt_p,
            "decode_tok_s": pall_dec_tok_s,
            "decode_speedup": pall_dec_tok_s / max(cont_dec_tok_s, 1e-9),
            "tokens_equal": tokens_equal,
        },
        "slack": {
            **rep.to_dict(),
            "slack_J_saved": slack_j,
            "downshift_pairs": pairs,
        },
        "slo": slo.summary(),
    }
    save_json("bench_serve", payload)
    if check:
        assert tokens_equal, "pallas arm sampled different tokens than xla"
        assert pall_dec_tok_s >= cont_dec_tok_s, (
            f"continuous+pallas {pall_dec_tok_s:.1f} tok/s below "
            f"continuous {cont_dec_tok_s:.1f} tok/s (decode-bound)"
        )
        print(f"serve check OK: pallas decode {pall_dec_tok_s:.1f} >= "
              f"xla {cont_dec_tok_s:.1f} tok/s, tokens bit-identical")
    return payload


def run_fleet(full: bool = False) -> dict:
    """Fleet headline: static-N vs SLO-autoscaled replicas under one watt
    cap on the diurnal trace (virtual clock, deterministic), plus the
    prefix-cache hit rate on the session-reuse trace.

    The claim the numbers must carry: the autoscaled fleet spends fewer
    joules per token at *no worse* SLO attainment, because off-peak it
    sheds replicas the static fleet keeps idling at the arbiter floor.
    """
    from repro.configs import get_config, reduced
    from repro.serve.fleet.fleet import FleetConfig, FleetSim
    from repro.serve.fleet.scenarios import diurnal_trace, session_reuse_trace

    cfg = reduced(get_config("llama3.2-1b"))
    duration = 90.0 if full else 60.0
    trace = diurnal_trace(duration_s=duration, base_rate=2.0, peak_ratio=8,
                          seed=0)

    def fleet_cfg(autoscale: bool) -> FleetConfig:
        return FleetConfig(cfg=cfg, n_replicas=3, autoscale=autoscale,
                           min_replicas=1, cap_w=40.0, floor_w=4.0,
                           step_s=0.01, ttft_target=1.5)

    results = {}
    for mode, autoscale in (("static", False), ("autoscaled", True)):
        t0 = time.monotonic()
        res = FleetSim(fleet_cfg(autoscale)).run(trace)
        wall = time.monotonic() - t0
        results[mode] = res
        emit(f"serve.fleet_{mode}",
             wall * 1e6 / max(res.tokens_out, 1),
             f"j_per_tok={res.joules_per_token:.4f}"
             f";ttft_att={res.ttft_attainment:.3f}"
             f";peak_replicas={res.n_replicas_peak}"
             f";ups={res.n_scale_ups};downs={res.n_scale_downs}")

    s, a = results["static"], results["autoscaled"]
    win = (a.joules_per_token < s.joules_per_token
           and a.ttft_attainment >= s.ttft_attainment)
    emit("serve.fleet_headline",
         (s.joules_per_token - a.joules_per_token) * 1e6,
         f"saving_pct={100 * (1 - a.joules_per_token / s.joules_per_token):.1f}"
         f";win={win};cap_ok={a.max_alloc_sum_w <= a.cap_w + 1e-9}")

    reuse = FleetSim(fleet_cfg(False)).run(session_reuse_trace(seed=1))
    emit("serve.fleet_prefix", reuse.prefix_hit_rate * 1e6,
         f"hit_rate={reuse.prefix_hit_rate:.3f}"
         f";lookups={reuse.prefix_lookups};hits={reuse.prefix_hits}")

    payload = {
        "trace": {"name": trace.name, "duration_s": duration,
                  "n_requests": trace.n_requests, "seed": trace.seed},
        "static": s.to_dict(),
        "autoscaled": a.to_dict(),
        "session_reuse": reuse.to_dict(),
        "headline": {
            "joules_per_token_static": s.joules_per_token,
            "joules_per_token_autoscaled": a.joules_per_token,
            "saving_pct": 100 * (1 - a.joules_per_token / s.joules_per_token),
            "autoscaled_wins": win,
            "cap_never_exceeded": a.max_alloc_sum_w <= a.cap_w + 1e-9,
            "prefix_hit_rate": reuse.prefix_hit_rate,
        },
    }
    save_json("bench_serve_fleet", payload)
    return payload


if __name__ == "__main__":
    import sys

    print("name,us_per_call,derived")
    if "fleet" in sys.argv[1:]:
        run_fleet(full="--full" in sys.argv)
    else:
        run(full="--full" in sys.argv, check="--check" in sys.argv)
