"""Render the roofline appendix (markdown) from artifacts/dryrun into
EXPERIMENTS.md §Appendix.  Run after the sweep:
    PYTHONPATH=src python scripts/roofline_report.py
"""
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.roofline import analyze_cell  # noqa: E402

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
EXP = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
MARK = "\n## Appendix — full roofline table"


def fmt(x):
    return f"{x:.2e}"


def main() -> None:
    rows, skips, multipod_ok, errors = [], [], [], []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        rec = json.load(open(path))
        tag = rec.get("tag", "")
        if rec["status"] == "skipped":
            skips.append((rec["arch"], rec["shape"], rec["mesh"]))
            continue
        if rec["status"] == "error":
            errors.append((rec["arch"], rec["shape"], rec["mesh"], tag,
                           rec.get("error", "")[:90]))
            continue
        if "cost" not in rec:                      # scan-only (compile+memory)
            multipod_ok.append(
                (rec["arch"], rec["shape"], rec["mesh"], tag,
                 rec["memory"]["peak_args_plus_temp"] / 2**30)
            )
            continue
        row = analyze_cell(rec)
        if row:
            rows.append(row)

    lines = [MARK, "", "Single-pod (16x16 = 256 chips) measured cells "
             "(terms in seconds/step/chip; frac = ideal-compute / bound):", ""]
    lines.append("| arch | shape | variant | compute | memory | collective "
                 "| dominant | frac | useful | peak GiB |")
    lines.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["tag"])):
        if r["mesh"] != "pod":
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['tag'] or 'baseline'} "
            f"| {fmt(r['compute_s'])} | {fmt(r['memory_s'])} "
            f"| {fmt(r['collective_s'])} | {r['dominant']} "
            f"| {r['roofline_fraction']:.3f} | {r['useful_flops_ratio']:.2f} "
            f"| {r['peak_mem_gib']:.2f} |"
        )
    lines += ["", f"Skipped cells (long_500k x full-attention archs, per "
              f"assignment): {len(skips)}", ""]
    if multipod_ok:
        lines += ["Compile-success + memory cells (multi-pod 2x16x16 = 512 "
                  "chips, scan-only; plus tagged memory variants):", ""]
        lines.append("| arch | shape | mesh | variant | peak GiB/chip |")
        lines.append("|---|---|---|---|---|")
        for a, s, me, t, m in sorted(multipod_ok):
            lines.append(f"| {a} | {s} | {me} | {t or '-'} | {m:.2f} |")
    if errors:
        lines += ["", "Cells with recorded errors:", ""]
        for a, s, m, t, e in errors:
            lines.append(f"- {a} {s} {m} {t}: `{e}`")
    lines.append("")

    text = open(EXP).read()
    if MARK in text:
        text = text[: text.index(MARK)]
    with open(EXP, "w") as f:
        f.write(text + "\n".join(lines))
    print(f"appendix written: {len(rows)} measured, {len(multipod_ok)} "
          f"multipod, {len(skips)} skipped, {len(errors)} errors")


if __name__ == "__main__":
    main()
