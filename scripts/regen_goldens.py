#!/usr/bin/env python
"""Regenerate the golden-report fixtures for tests/test_golden.py.

Run after an *intentional* change to the governor's accounting math::

    python scripts/regen_goldens.py

then review the diff of ``tests/goldens/*.json`` — every changed number is
a behavior change the commit message must justify.  The conformance suite
compares against these files with a pinned tolerance, so an accidental
refactor that shifts energy/overhead numbers fails loudly instead of
drifting silently.
"""
from __future__ import annotations

import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))
sys.path.insert(0, os.path.join(REPO, "tests"))

from golden_common import (CANNED, GOLDEN_POLICY_NAMES,  # noqa: E402
                           PREDICTIVE_POLICY_NAMES, predictive_entry,
                           report_dict)
from repro.core.policies import ALL_POLICIES  # noqa: E402

GOLDEN_DIR = os.path.join(REPO, "tests", "goldens")


def regen_perfetto() -> None:
    """Refresh the golden Perfetto trace (tests/test_obs.py); the canned
    capture itself lives beside the test so both stay in lockstep."""
    import conftest  # noqa: E402,F401 — registers the hypothesis fallback
    from test_obs import golden_tracer  # noqa: E402

    path = os.path.join(GOLDEN_DIR, "perfetto.json")
    with open(path, "w") as f:
        f.write(json.dumps(golden_tracer().build(), sort_keys=True))
    print(f"wrote {path}")


def main() -> None:
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    if "--perfetto" in sys.argv:
        regen_perfetto()
        return
    regen_perfetto()
    for kind in CANNED:
        payload = {
            "workload": kind,
            "policies": {
                name: report_dict(ALL_POLICIES[name], kind)
                for name in GOLDEN_POLICY_NAMES
            },
        }
        path = os.path.join(GOLDEN_DIR, f"{kind}.json")
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {path} ({len(payload['policies'])} policies)")
    # the predictive pair (hybrid + prediction-only strawman) pins into its
    # own fixture so the fixed-policy goldens stay byte-identical across the
    # predictive subsystem's evolution
    payload = {
        "policies": {
            name: {kind: predictive_entry(ALL_POLICIES[name], kind)
                   for kind in CANNED}
            for name in PREDICTIVE_POLICY_NAMES
        },
    }
    path = os.path.join(GOLDEN_DIR, "predictive.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {path} ({len(payload['policies'])} policies x "
          f"{len(CANNED)} streams)")


if __name__ == "__main__":
    main()
